"""Kernel sweeps: every Pallas kernel (interpret mode) and the chunked JAX
implementations against the pure-jnp oracles in ref.py, across shapes and
dtypes; custom_vjp gradients against autodiff of the oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.decode_attention import decode_attention as pallas_decode
from repro.kernels.flash_attention import flash_attention as pallas_flash
from repro.kernels.paged_attention import paged_attention as pallas_paged
from repro.kernels.rmsnorm import rmsnorm as pallas_rmsnorm
from repro.kernels.ssd_scan import ssd as pallas_ssd

_RNG = np.random.default_rng(42)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=5e-5, atol=5e-5)


def _mk(shape, dtype=jnp.float32, scale=1.0):
    return jnp.asarray(_RNG.normal(size=shape) * scale, dtype)


ATTN_SHAPES = [
    # b, sq, sk, h, kvh, d
    (1, 16, 16, 2, 2, 8),       # MHA
    (2, 33, 33, 4, 1, 16),      # MQA, ragged
    (2, 64, 64, 8, 2, 32),      # GQA
    (1, 24, 48, 4, 4, 64),      # cross-ish (sk > sq)
]
ATTN_OPTS = [
    dict(causal=True),
    dict(causal=False),
    dict(causal=True, window=9),
    dict(causal=True, softcap=11.0),
]


@pytest.mark.parametrize("shape", ATTN_SHAPES)
@pytest.mark.parametrize("opts", ATTN_OPTS)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_jnp_vs_ref(shape, opts, dtype):
    b, sq, sk, h, kvh, d = shape
    q, k, v = _mk((b, sq, h, d), dtype), _mk((b, sk, kvh, d), dtype), _mk((b, sk, kvh, d), dtype)
    off = max(sk - sq, 0)
    a = ref.attention(q, k, v, q_offset=off, **opts)
    f = ops.flash_attention_jnp(q, k, v, q_offset=off, block_k=16, **opts)
    np.testing.assert_allclose(
        np.asarray(a, np.float32), np.asarray(f, np.float32), **_tol(dtype)
    )


@pytest.mark.parametrize("shape", ATTN_SHAPES)
@pytest.mark.parametrize("opts", ATTN_OPTS)
def test_pallas_flash_vs_ref(shape, opts):
    b, sq, sk, h, kvh, d = shape
    q, k, v = _mk((b, sq, h, d)), _mk((b, sk, kvh, d)), _mk((b, sk, kvh, d))
    off = max(sk - sq, 0)
    a = ref.attention(q, k, v, q_offset=off, **opts)
    f = pallas_flash(q, k, v, q_offset=off, block_q=16, block_k=16, **opts)
    np.testing.assert_allclose(np.asarray(a), np.asarray(f), rtol=5e-5, atol=5e-5)


def test_pallas_flash_bf16():
    b, sq, sk, h, kvh, d = 2, 32, 32, 4, 2, 16
    q, k, v = (
        _mk((b, sq, h, d), jnp.bfloat16),
        _mk((b, sk, kvh, d), jnp.bfloat16),
        _mk((b, sk, kvh, d), jnp.bfloat16),
    )
    a = ref.attention(q, k, v)
    f = pallas_flash(q, k, v, block_q=16, block_k=16)
    np.testing.assert_allclose(
        np.asarray(a, np.float32), np.asarray(f, np.float32), rtol=3e-2, atol=3e-2
    )


@pytest.mark.parametrize("opts", ATTN_OPTS)
def test_flash_custom_vjp_grads(opts):
    b, sq, sk, h, kvh, d = 2, 24, 24, 4, 2, 16
    q, k, v = _mk((b, sq, h, d)), _mk((b, sk, kvh, d)), _mk((b, sk, kvh, d))
    do = _mk((b, sq, h, d))
    f_ref = lambda q, k, v: ref.attention(q, k, v, **opts)
    f_fla = lambda q, k, v: ops.flash_attention_jnp(q, k, v, block_k=8, **opts)
    o_r, vjp_r = jax.vjp(f_ref, q, k, v)
    o_f, vjp_f = jax.vjp(f_fla, q, k, v)
    np.testing.assert_allclose(o_r, o_f, rtol=3e-5, atol=3e-5)
    for g_r, g_f, name in zip(vjp_r(do), vjp_f(do), "qkv"):
        np.testing.assert_allclose(
            g_r, g_f, rtol=5e-4, atol=5e-4, err_msg=f"d{name} mismatch {opts}"
        )


DECODE_SHAPES = [
    (2, 16, 4, 2, 8),
    (3, 40, 4, 1, 16),
    (1, 64, 8, 8, 32),
]


@pytest.mark.parametrize("shape", DECODE_SHAPES)
@pytest.mark.parametrize("opts", [dict(), dict(softcap=7.0), dict(window=5)])
def test_pallas_decode_vs_ref(shape, opts):
    b, S, h, kvh, d = shape
    q = _mk((b, 1, h, d))
    kc, vc = _mk((b, S, kvh, d)), _mk((b, S, kvh, d))
    lengths = jnp.asarray(_RNG.integers(1, S + 1, size=(b,)), jnp.int32)
    a = ref.decode_attention(q, kc, vc, lengths, **opts)
    f = pallas_decode(q, kc, vc, lengths, block_s=16, **opts)
    np.testing.assert_allclose(np.asarray(a), np.asarray(f), rtol=5e-5, atol=5e-5)


# ---------------------------------------------------------------------------
# Paged decode attention: the Pallas kernel must equal DENSE attention over
# the same live tokens, for any scattering of those tokens across pages.
# ---------------------------------------------------------------------------
PAGED_SHAPES = [
    # b, S, h, kvh, d, page_size
    (2, 24, 4, 2, 8, 8),        # GQA, divisible
    (3, 40, 4, 1, 16, 16),      # MQA, S not a multiple of page_size
    (1, 64, 8, 8, 32, 16),      # MHA
]


def _paginate(kc, vc, page_size, rng):
    from repro.serve.page_table import scatter_cache_to_pages

    kp, vp, pt = scatter_cache_to_pages(kc, vc, page_size, rng)
    return jnp.asarray(kp), jnp.asarray(vp), jnp.asarray(pt)


@pytest.mark.parametrize("shape", PAGED_SHAPES)
@pytest.mark.parametrize("opts", [dict(), dict(softcap=7.0), dict(window=5)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pallas_paged_vs_dense_ref(shape, opts, dtype):
    b, S, h, kvh, d, ps = shape
    rng = np.random.default_rng(int(S + ps))
    q = _mk((b, 1, h, d), dtype)
    kc, vc = _mk((b, S, kvh, d), dtype), _mk((b, S, kvh, d), dtype)
    lengths = jnp.asarray(rng.integers(1, S + 1, size=(b,)), jnp.int32)
    kp, vp, pt = _paginate(kc, vc, ps, rng)
    a = ref.decode_attention(q, kc, vc, lengths, **opts)
    f = pallas_paged(q, kp, vp, pt, lengths, **opts)
    np.testing.assert_allclose(
        np.asarray(a, np.float32), np.asarray(f, np.float32), **_tol(dtype)
    )
    # the gather-based oracle agrees too (it backs the flash/ref serving path)
    r = ref.paged_attention(q, kp, vp, pt, lengths, **opts)
    np.testing.assert_allclose(
        np.asarray(a, np.float32), np.asarray(r, np.float32), **_tol(dtype)
    )


def test_pallas_paged_pages_bound():
    """Bounding the kv grid at the live page count must not change results."""
    b, S, h, kvh, d, ps = 2, 48, 4, 2, 16, 8
    rng = np.random.default_rng(5)
    q = _mk((b, 1, h, d))
    kc, vc = _mk((b, S, kvh, d)), _mk((b, S, kvh, d))
    lengths = jnp.asarray([11, 19], jnp.int32)   # live pages: 2 and 3 of 6
    kp, vp, pt = _paginate(kc, vc, ps, rng)
    full = pallas_paged(q, kp, vp, pt, lengths)
    bounded = pallas_paged(q, kp, vp, pt, lengths, pages_bound=3)
    np.testing.assert_allclose(np.asarray(full), np.asarray(bounded), rtol=5e-5, atol=5e-5)
    via_ops = ops.paged_attention(q, kp, vp, pt, lengths, backend="pallas", pages_bound=3)
    np.testing.assert_allclose(np.asarray(full), np.asarray(via_ops), rtol=5e-5, atol=5e-5)


def test_decode_attention_kv_bound():
    """Dense decode with a kv grid bounded by max(lengths) equals the
    unbounded kernel (blocks past the bound are fully masked anyway)."""
    b, S, h, kvh, d = 2, 64, 4, 2, 16
    q = _mk((b, 1, h, d))
    kc, vc = _mk((b, S, kvh, d)), _mk((b, S, kvh, d))
    lengths = jnp.asarray([7, 13], jnp.int32)
    full = pallas_decode(q, kc, vc, lengths, block_s=16)
    bounded = pallas_decode(q, kc, vc, lengths, block_s=16, kv_bound=16)
    np.testing.assert_allclose(np.asarray(full), np.asarray(bounded), rtol=5e-5, atol=5e-5)
    for backend in ("ref", "flash", "pallas"):
        out = ops.decode_attention(q, kc, vc, lengths, backend=backend, kv_bound=16)
        np.testing.assert_allclose(np.asarray(full), np.asarray(out), rtol=5e-5, atol=5e-5)


SSD_SHAPES = [
    # b, s, h, p, n, chunk
    (1, 16, 2, 4, 8, 4),
    (2, 40, 4, 8, 16, 8),
    (1, 64, 3, 16, 32, 16),     # h not power of two
]


@pytest.mark.parametrize("shape", SSD_SHAPES)
@pytest.mark.parametrize("with_init", [False, True])
def test_ssd_chunked_and_pallas_vs_ref(shape, with_init):
    b, s, h, p, n, chunk = shape
    x = _mk((b, s, h, p))
    dt = jnp.asarray(_RNG.uniform(0.01, 0.2, size=(b, s, h)), jnp.float32)
    A = jnp.asarray(-_RNG.uniform(0.5, 2.0, size=(h,)), jnp.float32)
    B, C = _mk((b, s, n)), _mk((b, s, n))
    init = _mk((b, h, p, n)) if with_init else None
    y_ref, S_ref = ref.ssd(x, dt, A, B, C, initial_state=init, return_state=True)
    y_chk, S_chk = ops.ssd_chunked_jnp(
        x, dt, A, B, C, chunk=chunk, initial_state=init, return_state=True
    )
    np.testing.assert_allclose(y_ref, y_chk, rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(S_ref, S_chk, rtol=5e-4, atol=5e-4)
    y_pal, S_pal = pallas_ssd(
        x, dt, A, B, C, chunk=chunk, initial_state=init, return_state=True
    )
    np.testing.assert_allclose(y_ref, y_pal, rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(S_ref, S_pal, rtol=5e-4, atol=5e-4)


def test_ssd_decode_step_consistency():
    b, s, h, p, n = 2, 12, 2, 4, 8
    x = _mk((b, s, h, p))
    dt = jnp.asarray(_RNG.uniform(0.01, 0.2, size=(b, s, h)), jnp.float32)
    A = jnp.asarray(-_RNG.uniform(0.5, 2.0, size=(h,)), jnp.float32)
    B, C = _mk((b, s, n)), _mk((b, s, n))
    y_full, S_full = ref.ssd(x, dt, A, B, C, return_state=True)
    _, S_part = ref.ssd(
        x[:, :-1], dt[:, :-1], A, B[:, :-1], C[:, :-1], return_state=True
    )
    y_step, S_step = ops.ssd_step(
        x[:, -1], dt[:, -1], A, B[:, -1], C[:, -1], S_part
    )
    np.testing.assert_allclose(y_step, y_full[:, -1], rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(S_step, S_full, rtol=5e-4, atol=5e-4)


@pytest.mark.parametrize("rows,D", [(1, 8), (17, 64), (64, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pallas_rmsnorm_vs_ref(rows, D, dtype):
    x = _mk((rows, D), dtype)
    w = _mk((D,), jnp.float32, 0.1)
    a = ref.rmsnorm(x, w, eps=1e-5)
    f = pallas_rmsnorm(x, w, eps=1e-5, block_rows=8)
    np.testing.assert_allclose(
        np.asarray(a, np.float32), np.asarray(f, np.float32), **_tol(dtype)
    )


def test_ops_backend_dispatch():
    q, k, v = _mk((1, 8, 2, 8)), _mk((1, 8, 2, 8)), _mk((1, 8, 2, 8))
    for backend in ("ref", "flash", "pallas"):
        out = ops.attention(q, k, v, backend=backend)
        assert out.shape == q.shape
    with pytest.raises(ValueError):
        ops.attention(q, k, v, backend="bogus")
