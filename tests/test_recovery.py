"""Live KV page migration: O(bytes) failover, elastic drain/join, and
corruption-detecting page checksums.

Three layers:

* unit tests pin the building blocks — the jitted page export/import
  round-trip, the per-page CRC ledger (any byte flip is caught), the
  corrupt fault's defer-until-a-snapshot-exists contract, duplicate
  fault-plan rejection, the serve_paged checkpoint knob validation, and
  drain/join over stub engines;
* a property-style test drives PagePool through random
  alloc/incref/free sequences and asserts the allocator invariants that
  migration leans on (free list disjoint from in-use, refcounts never
  negative, capacity conserved) — with and without the quantized-mode
  mirror pool in lockstep;
* integration tests run the full recovery matrix {crash, stall, drain,
  corrupt} x {spec_k 0/2} x {prefix cache on/off} x {kv f32/int8} over
  real paged engines and require every completed request to be
  BIT-IDENTICAL to the fault-free oracle — a migrated continuation must
  be indistinguishable from an undisturbed run, and a corrupted snapshot
  must be detected and downgraded to replay, never served.
"""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.analysis import recovery_summary
from repro.core.manifest import EngineKnobs
from repro.core.tracing import Tracer, TracingServer
from repro.serve.engine import ServeRequest
from repro.serve.faults import FaultContext, FaultPlan, FaultSpec, WorkerDrain
from repro.serve.fleet import FleetConfig, FleetRouter
from repro.serve.page_table import PagePool, PageSnapshot, page_checksums

from test_fleet import StubEngine, VirtualTime, _reqs


# ---------------------------------------------------------------------------
# ops.export_pages / ops.import_pages round-trip
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("quantized", [False, True], ids=["f32", "int8"])
def test_export_import_roundtrip(quantized):
    """Gather pages out of one pool, scatter them into another: the
    destination pages must hold the exact source bytes (and only the
    addressed pages may change)."""
    import jax.numpy as jnp

    from repro.kernels import ops

    L, P, S, H, D = 2, 6, 4, 2, 3
    rng = np.random.default_rng(0)
    if quantized:
        k = rng.integers(-128, 128, (L, P, S, H, D)).astype(np.int8)
        v = rng.integers(-128, 128, (L, P, S, H, D)).astype(np.int8)
        ks = rng.random((L, P, S, H)).astype(np.float32)
        vs = rng.random((L, P, S, H)).astype(np.float32)
    else:
        k = rng.random((L, P, S, H, D)).astype(np.float32)
        v = rng.random((L, P, S, H, D)).astype(np.float32)
        ks = vs = None

    idx = jnp.array([3, 1, 4], dtype=jnp.int32)
    out = ops.export_pages(jnp.asarray(k), jnp.asarray(v), idx,
                           None if ks is None else jnp.asarray(ks),
                           None if vs is None else jnp.asarray(vs))
    k_snap, v_snap = np.asarray(out[0]), np.asarray(out[1])
    assert np.array_equal(k_snap, k[:, [3, 1, 4]])
    assert np.array_equal(v_snap, v[:, [3, 1, 4]])
    if quantized:
        assert np.array_equal(np.asarray(out[2]), ks[:, [3, 1, 4]])
        assert np.array_equal(np.asarray(out[3]), vs[:, [3, 1, 4]])

    dst_k = jnp.zeros_like(jnp.asarray(k))
    dst_v = jnp.zeros_like(jnp.asarray(v))
    dst = jnp.array([2, 5, 1], dtype=jnp.int32)
    if quantized:
        dk, dv, dks, dvs = ops.import_pages(
            dst_k, dst_v, dst, out[0], out[1],
            jnp.zeros_like(jnp.asarray(ks)), jnp.zeros_like(jnp.asarray(vs)),
            out[2], out[3])
        assert np.array_equal(np.asarray(dks)[:, [2, 5, 1]], ks[:, [3, 1, 4]])
        assert np.array_equal(np.asarray(dvs)[:, [2, 5, 1]], vs[:, [3, 1, 4]])
    else:
        dk, dv = ops.import_pages(dst_k, dst_v, dst, out[0], out[1])
    dk, dv = np.asarray(dk), np.asarray(dv)
    assert np.array_equal(dk[:, [2, 5, 1]], k[:, [3, 1, 4]])
    assert np.array_equal(dv[:, [2, 5, 1]], v[:, [3, 1, 4]])
    untouched = [p for p in range(P) if p not in (2, 5, 1)]
    assert not dk[:, untouched].any() and not dv[:, untouched].any()


# ---------------------------------------------------------------------------
# page_checksums / PageSnapshot
# ---------------------------------------------------------------------------
def _snapshot(quantized=False, pages=3, seed=0):
    L, S, H, D = 2, 4, 2, 3
    rng = np.random.default_rng(seed)
    if quantized:
        k = rng.integers(-128, 128, (L, pages, S, H, D)).astype(np.int8)
        v = rng.integers(-128, 128, (L, pages, S, H, D)).astype(np.int8)
        ks = rng.random((L, pages, S, H)).astype(np.float32)
        vs = rng.random((L, pages, S, H)).astype(np.float32)
    else:
        k = rng.random((L, pages, S, H, D)).astype(np.float32)
        v = rng.random((L, pages, S, H, D)).astype(np.float32)
        ks = vs = None
    return PageSnapshot(
        request_id=7, prompt_len=5, length=9,
        tokens=np.arange(4, dtype=np.int32),
        k=k, v=v, k_scales=ks, v_scales=vs,
        checksums=page_checksums(k, v, ks, vs),
        kv_dtype="int8" if quantized else "float32",
    )


@pytest.mark.parametrize("quantized", [False, True], ids=["f32", "int8"])
def test_page_checksums_catch_any_byte_flip(quantized):
    snap = _snapshot(quantized)
    assert snap.verify()
    # a single flipped byte in any page, any array, fails ONLY that page
    for arr_name in ("k", "v") + (("k_scales", "v_scales") if quantized else ()):
        arr = np.array(getattr(snap, arr_name), copy=True)
        flat = arr.view(np.uint8).reshape(arr.shape[0], arr.shape[1], -1)
        flat[1, 2, -1] ^= 0x01
        fresh = {
            "k": snap.k, "v": snap.v,
            "k_scales": snap.k_scales, "v_scales": snap.v_scales,
            arr_name: arr,
        }
        sums = page_checksums(fresh["k"], fresh["v"],
                              fresh["k_scales"], fresh["v_scales"])
        assert sums[2] != snap.checksums[2], arr_name
        assert sums[:2] == snap.checksums[:2], arr_name


def test_page_snapshot_corrupt_is_detected_even_on_readonly_arrays():
    snap = _snapshot()
    # device-fetched snapshots arrive as read-only numpy views; corrupt()
    # must still work (it takes a writable copy) and verify() must catch it
    snap.k.setflags(write=False)
    before = snap.k.copy()
    snap.corrupt(page=0)
    assert not snap.verify()
    assert not np.array_equal(snap.k[:, 0], before[:, 0])
    assert np.array_equal(snap.k[:, 1:], before[:, 1:])  # one page bitten
    assert snap.nbytes == snap.k.nbytes + snap.v.nbytes
    assert snap.num_pages == 3


# ---------------------------------------------------------------------------
# corrupt fault semantics + fault-plan hygiene
# ---------------------------------------------------------------------------
def test_corrupt_fault_defers_until_a_snapshot_exists():
    plan = FaultPlan([FaultSpec("corrupt", 0, 1)])
    hook = plan.hook_for(0)
    store = {}
    # no checkpoints yet: the fault stays armed past its step
    for step in (1, 2):
        hook(FaultContext(step=step, checkpoints=store))
    assert not hook.fired
    snap = _snapshot()
    store[snap.request_id] = snap
    hook(FaultContext(step=3, checkpoints=store))
    assert [s.step for s in hook.fired] == [1]
    assert not snap.verify()            # bitten, ledger left stale
    # and it fired exactly once
    hook(FaultContext(step=4, checkpoints=store))
    assert len(hook.fired) == 1


def test_corrupt_bites_the_latest_snapshot():
    plan = FaultPlan([FaultSpec("corrupt", 0, 0)])
    hook = plan.hook_for(0)
    older, newer = _snapshot(seed=1), _snapshot(seed=2)
    older.step, newer.step = 2, 5
    older.request_id, newer.request_id = 1, 3
    store = {1: older, 3: newer}
    hook(FaultContext(step=0, checkpoints=store))
    assert older.verify() and not newer.verify()


def test_duplicate_fault_plan_entries_rejected():
    with pytest.raises(ValueError, match="duplicate fault"):
        FaultPlan.parse("crash@1:2,corrupt@1:2")
    with pytest.raises(ValueError, match="duplicate fault"):
        FaultPlan.parse("stall@0:3:0.1,stall@0:3:0.2")
    # same step on different workers is fine
    assert len(FaultPlan.parse("crash@0:2,crash@1:2").specs) == 2
    # corrupt round-trips through describe
    plan = FaultPlan.parse("corrupt@1:4,crash@1:5")
    assert FaultPlan.parse(plan.describe()).describe() == plan.describe()


def test_worker_drain_is_a_planned_crash():
    drain = WorkerDrain(2, 7)
    assert isinstance(drain, Exception)
    assert drain.reason == "drain"
    assert (drain.worker, drain.step) == (2, 7)


# ---------------------------------------------------------------------------
# EngineKnobs stamping (manifest)
# ---------------------------------------------------------------------------
def test_engine_knobs_record_recovery_configuration():
    stock = EngineKnobs(engine="paged", page_size=8)
    assert "recovery" not in stock.describe()      # old headers byte-stable
    armed = EngineKnobs(engine="paged", page_size=8,
                        recovery="migrate", checkpoint_every=4)
    assert "recovery=migrate checkpoint_every=4" in armed.describe()
    d = armed.to_dict()
    assert d["recovery"] == "migrate" and d["checkpoint_every"] == 4
    assert EngineKnobs.from_dict(d).describe() == armed.describe()


# ---------------------------------------------------------------------------
# check_regression: a missing metric is a named failure, not a traceback
# ---------------------------------------------------------------------------
def test_check_regression_missing_metric_fails_legibly(tmp_path, capsys):
    import json

    from benchmarks.check_regression import main as check

    cur = tmp_path / "cur.json"
    base = tmp_path / "base.json"
    cur.write_text(json.dumps({"paged": {"tokens_per_s": 10.0}}))
    base.write_text(json.dumps({"paged": {"tokens_per_s": 10.0}}))
    # metric present in both: passes
    assert check([str(cur), str(base),
                  "--metric", "paged.tokens_per_s"]) == 0
    # metric missing from the baseline: exit 1 with a named message
    assert check([str(cur), str(base),
                  "--metric", "paged.tokens_per_s",
                  "--metric", "recovery.recompute_ratio"]) == 1
    out = capsys.readouterr().out
    assert "MISSING METRIC" in out
    assert "recovery.recompute_ratio" in out
    assert str(cur) in out                 # names the offending file
    # lower-is-better metrics take the same path
    assert check([str(cur), str(base),
                  "--metric-lower", "corrupt.lost"]) == 1
    assert "MISSING METRIC" in capsys.readouterr().out


# ---------------------------------------------------------------------------
# PagePool invariants under random alloc/incref/free (property-style)
# ---------------------------------------------------------------------------
def _check_pool(pool: PagePool, model: dict) -> None:
    in_use = set(model)
    assert not (set(pool._free) & in_use)                 # disjoint
    assert pool.num_free + pool.num_in_use == pool.capacity
    for p, c in model.items():
        assert c >= 1
        assert pool.refcount(p) == c
    for p in pool._free:
        assert pool.refcount(p) == 0
    assert pool.num_shared == sum(1 for c in model.values() if c > 1)


@settings(max_examples=40)
@given(
    ops=st.lists(
        st.tuples(st.sampled_from(["alloc", "incref", "free"]),
                  st.integers(min_value=0, max_value=6)),
        min_size=0, max_size=60,
    ),
    mirrored=st.sampled_from([False, True]),
)
def test_page_pool_invariants_under_random_traffic(ops, mirrored):
    """Free list stays disjoint from in-use pages, refcounts never go
    negative, and capacity is conserved — under arbitrary interleavings of
    alloc/incref/free.  ``mirrored`` runs the identical sequence against a
    second pool (the quantized engine keeps scale arrays addressed by the
    SAME page ids, so allocation decisions must not depend on payload
    dtype): both pools stay in lockstep."""
    pools = [PagePool(num_pages=9, page_size=8)]
    if mirrored:
        pools.append(PagePool(num_pages=9, page_size=8))
    model: dict = {}
    for kind, arg in ops:
        if kind == "alloc":
            got = [p.alloc(arg) for p in pools]
            if got[0] is None:
                assert arg > pools[0].num_free
                assert all(g is None for g in got)
            else:
                assert all(g == got[0] for g in got)      # lockstep ids
                assert not (set(got[0]) & set(model))     # fresh pages only
                for p in got[0]:
                    model[p] = 1
        elif kind == "incref" and model:
            page = sorted(model)[arg % len(model)]
            for p in pools:
                p.incref([page])
            model[page] += 1
        elif kind == "free" and model:
            page = sorted(model)[arg % len(model)]
            released = [p.free([page]) for p in pools]
            assert all(r == released[0] for r in released)
            model[page] -= 1
            if model[page] == 0:
                assert released[0] == [page]
                del model[page]
            else:
                assert released[0] == []
        for p in pools:
            _check_pool(p, model)
    if mirrored:
        assert sorted(pools[0]._free) == sorted(pools[1]._free)


def test_page_pool_misuse_raises():
    pool = PagePool(num_pages=5, page_size=8)
    pages = pool.alloc(2)
    pool.free([pages[0]])
    with pytest.raises(ValueError, match="double free"):
        pool.free([pages[0]])
    with pytest.raises(ValueError, match="incref on free page"):
        pool.incref([pages[0]])
    with pytest.raises(ValueError, match="negative page count"):
        pool.alloc(-1)


# ---------------------------------------------------------------------------
# serve_paged checkpoint-knob validation (real engine, no decoding)
# ---------------------------------------------------------------------------
def test_checkpoint_knob_validation(fleet_engines):
    _, engines, _ = fleet_engines
    with pytest.raises(ValueError, match="checkpoint_every must be >= 0"):
        engines[0].serve_paged([], checkpoint_every=-1)
    with pytest.raises(ValueError, match="needs a checkpoints dict"):
        engines[0].serve_paged([], checkpoint_every=2)


# ---------------------------------------------------------------------------
# FleetRouter drain/join over stub engines (virtual clock)
# ---------------------------------------------------------------------------
def test_drain_is_not_a_death_and_requeues_everything():
    vt = VirtualTime()
    engines = [StubEngine(vt) for _ in range(3)]
    router = FleetRouter(engines, FleetConfig(),
                         clock=vt.clock, sleep=vt.sleep)
    router.drain(1, at_step=1)
    stats = router.serve(_reqs(9))
    assert stats.completed == 9
    assert stats.drains == 1 and stats.deaths == 0
    assert stats.failed == stats.rejected == 0
    # stub engines carry no snapshots: drained work replays on survivors
    assert stats.requeued > 0


def test_drain_validates_worker_index():
    vt = VirtualTime()
    router = FleetRouter([StubEngine(vt)], FleetConfig(),
                         clock=vt.clock, sleep=vt.sleep)
    with pytest.raises(ValueError, match="no worker"):
        router.drain(3)


def test_join_adds_a_worker_mid_serve():
    vt = VirtualTime()
    late = StubEngine(vt)
    # one worker admits 2x its 4 slots per round: 10 requests need a second
    # round, which is exactly when the joiner arrives
    router = FleetRouter([StubEngine(vt)], FleetConfig(),
                         clock=vt.clock, sleep=vt.sleep)
    assert router.join(late, at_round=1) == 1
    stats = router.serve(_reqs(10))
    assert stats.completed == 10
    assert stats.joins == 1
    assert stats.num_workers == 2
    assert late.calls > 0                   # the joiner actually served


def test_drain_then_join_rolls_the_fleet():
    vt = VirtualTime()
    engines = [StubEngine(vt) for _ in range(2)]
    router = FleetRouter(engines, FleetConfig(),
                         clock=vt.clock, sleep=vt.sleep)
    router.drain(0, at_step=0)
    router.join(StubEngine(vt), at_round=1)
    stats = router.serve(_reqs(8))
    assert stats.completed == 8
    assert stats.drains == 1 and stats.joins == 1 and stats.deaths == 0


# ---------------------------------------------------------------------------
# Integration: real paged engines, full recovery matrix, bit-identity
# ---------------------------------------------------------------------------
NUM_SLOTS, PAGE_SIZE, MAX_SEQ = 4, 8, 64
N_REQS, PROMPT_LEN, GEN = 6, 12, 8

# every scenario runs recovery="migrate"; the corrupt cell needs a cadence
# GAP between the corruption and the crash (a periodic refresh in between
# would heal the snapshot — correct behavior, but not what the cell tests)
SCENARIOS = {
    "crash": dict(plan="crash@1:2", checkpoint_every=1),
    "stall": dict(plan="stall@1:1:0.02", checkpoint_every=1),
    "drain": dict(plan="", checkpoint_every=0, drain=(1, 2)),
    "corrupt": dict(plan="corrupt@1:4,crash@1:5", checkpoint_every=3),
}


@pytest.fixture(scope="module", params=["float32", "int8"],
                ids=["f32", "int8"])
def fleet_engines(request):
    import jax

    from repro.configs import get_config
    from repro.models import build_model
    from repro.serve.engine import ServingEngine

    cfg = get_config("glm4-9b", reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    kv_dtype = None if request.param == "float32" else request.param
    # 3 fleet workers + 1 spare for the join scenario
    engines = [
        ServingEngine(model, params, max_batch=NUM_SLOTS, max_seq=MAX_SEQ,
                      page_size=PAGE_SIZE, kv_dtype=kv_dtype)
        for _ in range(4)
    ]
    rng = np.random.default_rng(0)
    shared = rng.integers(0, cfg.vocab_size, (8,)).astype(np.int32)
    prompts = [
        np.concatenate([
            shared,
            rng.integers(0, cfg.vocab_size,
                         (PROMPT_LEN - len(shared),)).astype(np.int32),
        ])
        for _ in range(N_REQS)
    ]
    return request.param, engines, prompts


_oracles = {}


def _serve(engines, prompts, plan, spec_k, prefix, tracer=None, **cfg_kw):
    reqs = [
        ServeRequest(request_id=i, prompt=p, max_new_tokens=GEN)
        for i, p in enumerate(prompts)
    ]
    router = FleetRouter(
        engines[:3], FleetConfig(recovery="migrate", **cfg_kw),
        engine_kwargs=dict(num_slots=NUM_SLOTS, page_size=PAGE_SIZE,
                           spec_k=spec_k, prefix_cache=prefix),
        fault_plan=FaultPlan.parse(plan) if plan else None,
        tracer=tracer,
    )
    return router, reqs


def _oracle(fleet_engines, spec_k, prefix):
    dtype, engines, prompts = fleet_engines
    key = (dtype, spec_k, prefix)
    if key not in _oracles:
        router, reqs = _serve(engines, prompts, "", spec_k, prefix)
        base = router.serve(reqs)
        assert base.completed == N_REQS
        _oracles[key] = {r.request_id: r.tokens for r in base.results}
    return _oracles[key]


@pytest.mark.parametrize("prefix", [True, False], ids=["prefix", "noprefix"])
@pytest.mark.parametrize("spec_k", [0, 2], ids=["spec0", "spec2"])
@pytest.mark.parametrize("kind", sorted(SCENARIOS))
def test_recovery_matrix_bit_identity(fleet_engines, kind, spec_k, prefix):
    dtype, engines, prompts = fleet_engines
    oracle = _oracle(fleet_engines, spec_k, prefix)
    sc = SCENARIOS[kind]

    router, reqs = _serve(engines, prompts, sc["plan"], spec_k, prefix,
                          checkpoint_every=sc["checkpoint_every"])
    if "drain" in sc:
        worker, at_step = sc["drain"]
        router.drain(worker, at_step=at_step)
        router.join(engines[3], at_round=1)
    stats = router.serve(reqs)

    label = f"{kind}/{dtype}/spec{spec_k}/prefix={prefix}"
    # zero silent loss, and this matrix has survivors: everything completes
    assert stats.completed + stats.failed + stats.rejected == N_REQS
    assert stats.completed == N_REQS, (
        f"{label}: "
        f"{[(r.request_id, r.status, r.reason) for r in stats.results]}"
    )
    # the O(bytes) contract: a migrated continuation is indistinguishable
    # from an undisturbed run
    for r in stats.results:
        assert np.array_equal(r.tokens, oracle[r.request_id]), (
            f"{label}: request {r.request_id} diverged after recovery"
        )

    if kind == "crash":
        assert stats.deaths == 1
        assert stats.migrated > 0 and stats.bytes_moved > 0, label
        assert stats.recomputed_prefill_tokens == 0, label
        assert stats.checksum_failures == 0, label
        assert stats.migrated_tokens > 0
    elif kind == "stall":
        # checkpointing armed on a run that never dies: pure overhead path,
        # nothing migrates, nothing recomputes, no checksum ever misses
        assert stats.deaths == 0 and stats.migrated == 0, label
        assert stats.checkpoints_saved > 0, label
        assert stats.checksum_failures == 0, label
    elif kind == "drain":
        assert stats.drains == 1 and stats.deaths == 0, label
        assert stats.joins == 1 and stats.num_workers == 4, label
        assert stats.migrated > 0, label
        assert stats.recomputed_prefill_tokens == 0, label
    elif kind == "corrupt":
        assert stats.deaths == 1, label
        # the bite was DETECTED at restore and downgraded to replay —
        # corrupted state is never served (bit-identity above proves it)
        assert stats.checksum_failures >= 1, label


def test_recovery_events_flow_to_analysis(fleet_engines):
    dtype, engines, prompts = fleet_engines
    if dtype != "float32":
        pytest.skip("tracing shape is dtype-independent")
    server = TracingServer()
    tracer = Tracer("t-recovery", server)
    router, reqs = _serve(engines, prompts, "crash@1:2", 0, False,
                          tracer=tracer, checkpoint_every=1)
    stats = router.serve(reqs)
    assert stats.migrated > 0

    summary = recovery_summary(server.timeline("t-recovery"))
    # the dead worker's engine counters are lost with its raised serve, but
    # its ckpt:save trace events survive: traced >= fleet-folded
    assert summary["checkpoints_saved"] >= float(stats.checkpoints_saved) > 0
    assert summary["checkpoint_bytes"] >= float(stats.checkpoint_bytes) > 0
    assert summary["migrated"] == float(stats.migrated)
    assert summary["migrated_tokens"] == float(stats.migrated_tokens)
    assert summary["bytes_moved"] == float(stats.bytes_moved)
    assert summary["recomputed_prefill_tokens"] == \
        float(stats.recomputed_prefill_tokens)
    assert summary["checksum_failures"] == 0.0
    assert summary["migrated_token_fraction"] == 1.0
    assert summary["restore_mean_s"] >= 0.0
    # and a run with no recovery activity renders no section at all
    assert recovery_summary([]) == {}
