"""Distributed registry: TTL leases, heartbeats, resolution, balancing."""
import pytest

from repro.core.manifest import ModelManifest, SystemRequirements
from repro.core.registry import AgentRecord, KVStore, Registry


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


@pytest.fixture
def clock():
    return FakeClock()


@pytest.fixture
def registry(clock):
    return Registry(store=KVStore(clock=clock))


def _agent(aid, models, backend="ref", load=0, system=None):
    return AgentRecord(
        agent_id=aid,
        backend=backend,
        backend_version="1.0.0",
        system=system or {"platform": "cpu", "num_devices": 1, "mesh": "host"},
        models=models,
        load=load,
    )


def test_ttl_expiry_removes_agent(registry, clock):
    registry.register_agent(_agent("a1", ["m:1.0.0"]))
    assert len(registry.agents()) == 1
    clock.t += Registry.AGENT_TTL + 1
    assert registry.agents() == []


def test_heartbeat_extends_lease(registry, clock):
    registry.register_agent(_agent("a1", ["m:1.0.0"]))
    for _ in range(5):
        clock.t += Registry.AGENT_TTL / 2
        assert registry.heartbeat("a1")
    assert len(registry.agents()) == 1
    clock.t += Registry.AGENT_TTL + 1
    assert not registry.heartbeat("a1")


def test_resolution_filters_and_orders(registry):
    registry.register_agent(_agent("busy", ["m:1.0.0"], load=5))
    registry.register_agent(_agent("idle", ["m:1.0.0"], load=0))
    registry.register_agent(_agent("other", ["x:1.0.0"], load=0))
    recs = registry.resolve("m:1.0.0")
    assert [r.agent_id for r in recs] == ["idle", "busy"]


def test_resolution_backend_and_system_constraints(registry):
    registry.register_agent(_agent("cpuagent", ["m:1.0.0"], backend="ref"))
    registry.register_agent(
        _agent("tpuagent", ["m:1.0.0"], backend="pallas",
               system={"platform": "tpu", "num_devices": 256, "mesh": "pod"})
    )
    assert [r.agent_id for r in registry.resolve("m:1.0.0", backend_name="pallas")] == ["tpuagent"]
    recs = registry.resolve(
        "m:1.0.0", requirements=SystemRequirements(platform="tpu", min_devices=256)
    )
    assert [r.agent_id for r in recs] == ["tpuagent"]


def test_manifest_version_resolution(registry):
    for v in ("1.0.0", "1.2.0", "2.0.0"):
        registry.register_manifest(
            ModelManifest(name="m", version=v, backend_constraint="")
        )
    best = registry.find_manifest("m", ">=1.0 <2.0")
    assert best.version == "1.2.0"
    assert registry.find_manifest("m").version == "2.0.0"
    assert registry.find_manifest("missing") is None


def test_dynamic_add_delete(registry):
    key = registry.register_manifest(ModelManifest(name="m", version="1.0.0"))
    assert registry.manifests("m")
    assert registry.unregister_manifest(key)
    assert registry.manifests("m") == []


def test_load_tracking(registry):
    registry.register_agent(_agent("a1", ["m:1.0.0"]))
    registry.update_load("a1", +2)
    assert registry.agents()[0].load == 2
    registry.update_load("a1", -1)
    assert registry.agents()[0].load == 1
    registry.update_load("a1", -5)
    assert registry.agents()[0].load == 0   # clamped


def test_kvstore_file_roundtrip(tmp_path, clock):
    store = KVStore(clock=clock)
    store.put("k/a", {"v": 1})
    store.put("k/b", {"v": 2}, ttl=100)
    path = str(tmp_path / "reg.json")
    store.dump(path)
    store2 = KVStore(clock=clock)
    store2.load(path)
    assert store2.get("k/a") == {"v": 1}
    assert [k for k, _ in store2.scan("k/")] == ["k/a", "k/b"]
