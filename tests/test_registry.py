"""Distributed registry: TTL leases, heartbeats, resolution, balancing."""
import pytest

from repro.core.manifest import ModelManifest, SystemRequirements
from repro.core.registry import AgentRecord, KVStore, Registry


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


@pytest.fixture
def clock():
    return FakeClock()


@pytest.fixture
def registry(clock):
    return Registry(store=KVStore(clock=clock))


def _agent(aid, models, backend="ref", load=0, system=None):
    return AgentRecord(
        agent_id=aid,
        backend=backend,
        backend_version="1.0.0",
        system=system or {"platform": "cpu", "num_devices": 1, "mesh": "host"},
        models=models,
        load=load,
    )


def test_ttl_expiry_removes_agent(registry, clock):
    registry.register_agent(_agent("a1", ["m:1.0.0"]))
    assert len(registry.agents()) == 1
    clock.t += Registry.AGENT_TTL + 1
    assert registry.agents() == []


def test_heartbeat_extends_lease(registry, clock):
    registry.register_agent(_agent("a1", ["m:1.0.0"]))
    for _ in range(5):
        clock.t += Registry.AGENT_TTL / 2
        assert registry.heartbeat("a1")
    assert len(registry.agents()) == 1
    clock.t += Registry.AGENT_TTL + 1
    assert not registry.heartbeat("a1")


def test_resolution_filters_and_orders(registry):
    registry.register_agent(_agent("busy", ["m:1.0.0"], load=5))
    registry.register_agent(_agent("idle", ["m:1.0.0"], load=0))
    registry.register_agent(_agent("other", ["x:1.0.0"], load=0))
    recs = registry.resolve("m:1.0.0")
    assert [r.agent_id for r in recs] == ["idle", "busy"]


def test_resolution_backend_and_system_constraints(registry):
    registry.register_agent(_agent("cpuagent", ["m:1.0.0"], backend="ref"))
    registry.register_agent(
        _agent("tpuagent", ["m:1.0.0"], backend="pallas",
               system={"platform": "tpu", "num_devices": 256, "mesh": "pod"})
    )
    assert [r.agent_id for r in registry.resolve("m:1.0.0", backend_name="pallas")] == ["tpuagent"]
    recs = registry.resolve(
        "m:1.0.0", requirements=SystemRequirements(platform="tpu", min_devices=256)
    )
    assert [r.agent_id for r in recs] == ["tpuagent"]


def test_manifest_version_resolution(registry):
    for v in ("1.0.0", "1.2.0", "2.0.0"):
        registry.register_manifest(
            ModelManifest(name="m", version=v, backend_constraint="")
        )
    best = registry.find_manifest("m", ">=1.0 <2.0")
    assert best.version == "1.2.0"
    assert registry.find_manifest("m").version == "2.0.0"
    assert registry.find_manifest("missing") is None


def test_dynamic_add_delete(registry):
    key = registry.register_manifest(ModelManifest(name="m", version="1.0.0"))
    assert registry.manifests("m")
    assert registry.unregister_manifest(key)
    assert registry.manifests("m") == []


def test_load_tracking(registry):
    registry.register_agent(_agent("a1", ["m:1.0.0"]))
    registry.update_load("a1", +2)
    assert registry.agents()[0].load == 2
    registry.update_load("a1", -1)
    assert registry.agents()[0].load == 1
    registry.update_load("a1", -5)
    assert registry.agents()[0].load == 0   # clamped


def test_kvstore_file_roundtrip(tmp_path, clock):
    store = KVStore(clock=clock)
    store.put("k/a", {"v": 1})
    store.put("k/b", {"v": 2}, ttl=100)
    path = str(tmp_path / "reg.json")
    store.dump(path)
    store2 = KVStore(clock=clock)
    store2.load(path)
    assert store2.get("k/a") == {"v": 1}
    assert [k for k, _ in store2.scan("k/")] == ["k/a", "k/b"]


def test_renew_after_expiry_refused(clock):
    store = KVStore(clock=clock)
    store.put("lease", {"v": 1}, ttl=10)
    clock.t = 5
    assert store.renew("lease", ttl=10)       # mid-lease heartbeat extends
    clock.t = 16                              # past the extended expiry
    assert not store.renew("lease", ttl=10)   # refused, never resurrects
    assert store.get("lease") is None
    assert not store.renew("lease", ttl=10)   # stays refused


def test_expired_entries_disappear_atomically_from_scan(clock):
    store = KVStore(clock=clock)
    store.put("a/1", {"v": 1}, ttl=10)
    store.put("a/2", {"v": 2}, ttl=100)
    clock.t = 50
    assert [k for k, _ in store.scan("a/")] == ["a/2"]
    # the expired entry was purged by the scan, not merely filtered
    assert not store.renew("a/1", ttl=10)


def test_lease_expiry_persists_across_dump_reload(tmp_path, clock):
    store = KVStore(clock=clock)
    store.put("lease/live", {"v": 1}, ttl=100)
    store.put("lease/dying", {"v": 2}, ttl=10)
    path = str(tmp_path / "reg.json")
    store.dump(path)
    clock.t = 50                    # between the two expiries
    store2 = KVStore(clock=clock)
    store2.load(path)
    assert store2.get("lease/dying") is None    # expiry survives the file
    assert store2.get("lease/live") == {"v": 1}
    assert not store2.renew("lease/dying", ttl=10)


def test_mutate_is_atomic_rmw(clock):
    store = KVStore(clock=clock)
    store.put("counter", {"n": 0})
    for _ in range(10):
        assert store.mutate("counter", lambda rec: {"n": rec["n"] + 1})
    assert store.get("counter") == {"n": 10}
    # mutate on an expired entry is refused (and purges it)
    store.put("lease", {"n": 0}, ttl=10)
    clock.t += 11
    assert not store.mutate("lease", lambda rec: rec)
    assert store.get("lease") is None


def test_get_and_scan_return_copies(clock):
    store = KVStore(clock=clock)
    store.put("k", {"n": 1})
    store.get("k")["n"] = 99
    assert store.get("k") == {"n": 1}
    for _, v in store.scan("k"):
        v["n"] = 99
    assert store.get("k") == {"n": 1}
