"""Across-stack tracing: levels, nesting, aggregation (F9)."""
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.tracing import (
    NullTracer,
    Span,
    Tracer,
    TraceLevel,
    TracingServer,
    summarize,
)


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        self.t += 1.0
        return self.t


def test_span_nesting_and_timeline():
    server = TracingServer()
    tr = Tracer("t1", server, TraceLevel.FULL, clock=FakeClock())
    with tr.span("outer", TraceLevel.MODEL) as outer:
        with tr.span("inner", TraceLevel.FRAMEWORK) as inner:
            pass
    tl = server.timeline("t1")
    assert [s.name for s in tl] == ["outer", "inner"]
    assert tl[1].parent_id == tl[0].span_id
    assert tl[0].duration >= tl[1].duration > 0


def test_trace_levels_filter():
    server = TracingServer()
    tr = Tracer("t1", server, TraceLevel.MODEL)
    with tr.span("model", TraceLevel.MODEL):
        with tr.span("framework", TraceLevel.FRAMEWORK):
            with tr.span("system", TraceLevel.SYSTEM):
                pass
    names = [s.name for s in server.timeline("t1")]
    assert names == ["model"]


def test_none_level_records_nothing():
    server = TracingServer()
    tr = Tracer("t1", server, TraceLevel.NONE)
    with tr.span("x", TraceLevel.MODEL):
        pass
    assert server.timeline("t1") == []
    nt = NullTracer()
    with nt.span("y"):
        pass


def test_full_level_records_everything():
    server = TracingServer()
    tr = Tracer("t1", server, TraceLevel.FULL)
    for lvl in (TraceLevel.MODEL, TraceLevel.FRAMEWORK, TraceLevel.SYSTEM):
        with tr.span(lvl.name, lvl):
            pass
    assert len(server.timeline("t1")) == 3


def test_out_of_order_async_publish_merges_sorted():
    server = TracingServer()
    s1 = Span("late", TraceLevel.MODEL, "t", begin=5.0, end=6.0)
    s2 = Span("early", TraceLevel.MODEL, "t", begin=1.0, end=2.0)
    server.publish(s1)
    server.publish(s2)
    assert [s.name for s in server.timeline("t")] == ["early", "late"]


def test_simulated_clock_supported():
    """The paper allows simulator-published (non-wall-clock) timestamps."""
    server = TracingServer()
    tr = Tracer("sim", server, TraceLevel.FULL, clock=FakeClock())
    with tr.span("simulated"):
        pass
    (sp,) = server.timeline("sim")
    assert sp.begin == 1.0 and sp.end == 2.0


def test_event_api_and_summary():
    server = TracingServer()
    tr = Tracer("t", server, TraceLevel.FULL)
    tr.event("ext", 0.0, 2.5, TraceLevel.SYSTEM, flops=100)
    tr.event("ext", 3.0, 4.0, TraceLevel.SYSTEM)
    agg = summarize(server.timeline("t"))
    assert agg["ext"]["count"] == 2
    assert agg["ext"]["total_s"] == pytest.approx(3.5)


def test_dump_load_roundtrip(tmp_path):
    server = TracingServer()
    tr = Tracer("t", server, TraceLevel.FULL)
    with tr.span("a", TraceLevel.MODEL, tag=1):
        pass
    path = str(tmp_path / "trace.json")
    server.dump("t", path)
    spans = TracingServer.load(path)
    assert spans[0].name == "a" and spans[0].tags == {"tag": 1}


@settings(max_examples=30, deadline=None)
@given(depth=st.integers(1, 8))
def test_nesting_depth_property(depth):
    """Parent chains always form a path back to the root span."""
    server = TracingServer()
    tr = Tracer("t", server, TraceLevel.FULL)

    def rec(d):
        if d == 0:
            return
        with tr.span(f"d{d}"):
            rec(d - 1)

    rec(depth)
    spans = {s.span_id: s for s in server.timeline("t")}
    assert len(spans) == depth
    roots = [s for s in spans.values() if s.parent_id is None]
    assert len(roots) == 1
    for s in spans.values():
        hops = 0
        cur = s
        while cur.parent_id is not None:
            cur = spans[cur.parent_id]
            hops += 1
            assert hops <= depth
