"""Quantized KV pages (int8/fp8): quantize/dequant bounds, fused-dequant
kernel parity against attending over a pre-dequantized pool, scale pools
moving with pages under COW, pool/table invariants with an attached scale
pool, flag-off bit-identity, quantized cross-mode token identity, the
divergence harness, engine-knob manifests, and lower-is-better regression
gating."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.analysis import kv_divergence_section, kv_divergence_summary
from repro.core.manifest import EngineKnobs
from repro.kernels import kvquant, ops, ref
from repro.kernels.paged_attention import paged_attention as pallas_paged
from repro.kernels.spec_verify import spec_verify as pallas_spec
from repro.kernels.varlen_prefill import varlen_prefill as pallas_varlen
from repro.models import build_model
from repro.serve.engine import ServeRequest, ServingEngine
from repro.serve.page_table import PagePool, PageTable

H, KVH, DH = 8, 4, 16
PAGE = 8

# fused-dequant kernels do scale * int8 in f32 exactly like the
# pre-dequantized oracle; only summation order differs
TOL = dict(rtol=1e-5, atol=5e-5)


# ---------------------------------------------------------------------------
# kvquant module
# ---------------------------------------------------------------------------
def test_is_quantized_modes():
    assert kvquant.is_quantized("int8")
    assert kvquant.is_quantized("fp8")
    for full in (None, "float32", "bfloat16", "float16", "f32", "bf16"):
        assert not kvquant.is_quantized(full)
    with pytest.raises(ValueError):
        kvquant.is_quantized("int4")


def test_pool_dtype_and_quant_max():
    assert kvquant.pool_dtype("int8") == "int8"
    assert kvquant.pool_dtype("fp8") == "float8_e4m3fn"
    assert kvquant.quant_max(jnp.int8) == 127.0
    assert kvquant.quant_max(jnp.float8_e4m3fn) == 448.0
    with pytest.raises(ValueError):
        kvquant.quant_max(jnp.float32)


@pytest.mark.parametrize("mode", ["int8", "fp8"])
def test_quantize_roundtrip_error_bound(mode):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((6, PAGE, KVH, DH)) * 3.0, jnp.float32)
    q, scales = kvquant.quantize(x, kvquant.pool_dtype(mode))
    assert q.shape == x.shape and scales.shape == x.shape[:-1]
    assert scales.dtype == jnp.float32
    deq = kvquant.dequantize(q, scales)
    # per-(row, head) error bound: half a quantization step for int8,
    # e4m3's ~2^-3 relative precision at the row amax for fp8
    amax = np.max(np.abs(np.asarray(x)), axis=-1, keepdims=True)
    step = amax / 127.0 if mode == "int8" else amax / 8.0
    assert np.all(np.abs(np.asarray(deq - x)) <= step + 1e-6)


def test_quantize_zero_rows_stay_zero():
    x = jnp.zeros((2, PAGE, KVH, DH), jnp.float32)
    q, scales = kvquant.quantize(x, "int8")
    assert np.all(np.asarray(scales) == 0.0)
    np.testing.assert_array_equal(np.asarray(kvquant.dequantize(q, scales)), 0.0)


def test_kv_bytes_per_token_math():
    L, kvh, dh = 3, 2, 64
    assert kvquant.kv_bytes_per_token(L, kvh, dh, "float32") == 2 * L * kvh * dh * 4
    assert kvquant.kv_bytes_per_token(L, kvh, dh, "bfloat16") == 2 * L * kvh * dh * 2
    # quantized: 1 byte payload + 4-byte f32 scale per row per head
    assert kvquant.kv_bytes_per_token(L, kvh, dh, "int8") == 2 * L * kvh * (dh + 4)
    assert kvquant.kv_bytes_per_token(L, kvh, dh, "fp8") == 2 * L * kvh * (dh + 4)


# ---------------------------------------------------------------------------
# fused-dequant kernels vs attending over the pre-dequantized pool
# ---------------------------------------------------------------------------
def _quantized_pools(rng, num_pages, mode):
    k = jnp.asarray(rng.standard_normal((num_pages, PAGE, KVH, DH)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((num_pages, PAGE, KVH, DH)), jnp.float32)
    store = kvquant.pool_dtype(mode)
    kq, ks = kvquant.quantize(k, store)
    vq, vs = kvquant.quantize(v, store)
    return (kq, ks, vq, vs), (kvquant.dequantize(kq, ks), kvquant.dequantize(vq, vs))


@pytest.mark.parametrize("mode", ["int8", "fp8"])
@pytest.mark.parametrize("impl", ["ref", "jnp", "pallas"])
def test_paged_attention_quantized(mode, impl):
    rng = np.random.default_rng(0)
    (kq, ks, vq, vs), (kd, vd) = _quantized_pools(rng, 24, mode)
    b, max_pages = 4, 4
    q = jnp.asarray(rng.standard_normal((b, 1, H, DH)), jnp.float32)
    table = jnp.asarray(
        rng.permutation(np.arange(1, 24))[: b * max_pages].reshape(b, max_pages),
        jnp.int32,
    )
    lengths = jnp.asarray([5, 13, 1, 27], jnp.int32)
    want = ref.paged_attention(q, kd, vd, table, lengths)

    def dispatch_ref(*a, **kw):
        return ops.paged_attention(*a, backend="ref", **kw)

    fn = {"ref": ref.paged_attention, "jnp": dispatch_ref,
          "pallas": pallas_paged}[impl]
    got = fn(q, kq, vq, table, lengths, k_scales=ks, v_scales=vs)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL)


@pytest.mark.parametrize("mode", ["int8", "fp8"])
@pytest.mark.parametrize("impl", ["ref", "jnp", "pallas"])
def test_varlen_prefill_quantized(mode, impl):
    rng = np.random.default_rng(1)
    (kq, ks, vq, vs), (kd, vd) = _quantized_pools(rng, 24, mode)
    C, max_pages = 4, 4
    spans = [16, 8, 24, 16]
    T = sum(spans)
    cu = np.zeros((C + 1,), np.int32)
    cu[1:] = np.cumsum(spans)
    chunk_lens = jnp.asarray([13, 8, 21, 10], jnp.int32)
    chunk_pos0 = jnp.asarray([0, 16, 8, 0], jnp.int32)
    tables = jnp.asarray(
        rng.permutation(np.arange(1, 24))[: C * max_pages].reshape(C, max_pages),
        jnp.int32,
    )
    # the packed chunk K/V stay full precision — only committed context
    # pages are quantized
    q = jnp.asarray(rng.standard_normal((T, H, DH)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((T, KVH, DH)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((T, KVH, DH)), jnp.float32)
    args = (q, k, v)
    rest = (jnp.asarray(cu), chunk_lens, chunk_pos0, tables)
    want = ref.varlen_prefill(*args, kd, vd, *rest)
    fn = {"ref": ref.varlen_prefill, "jnp": ops.varlen_prefill_jnp,
          "pallas": pallas_varlen}[impl]
    got = fn(*args, kq, vq, *rest, k_scales=ks, v_scales=vs)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL)


@pytest.mark.parametrize("mode", ["int8", "fp8"])
@pytest.mark.parametrize("impl", ["ref", "jnp", "pallas"])
def test_spec_verify_quantized(mode, impl):
    rng = np.random.default_rng(2)
    (kq, ks, vq, vs), (kd, vd) = _quantized_pools(rng, 24, mode)
    b, W, max_pages = 4, 3, 4
    q = jnp.asarray(rng.standard_normal((b, W, H, DH)), jnp.float32)
    table = jnp.asarray(
        rng.permutation(np.arange(1, 24))[: b * max_pages].reshape(b, max_pages),
        jnp.int32,
    )
    lengths = jnp.asarray([5, 14, 3, 26], jnp.int32)
    window_lens = jnp.asarray([3, 1, 0, 2], jnp.int32)
    want = ref.spec_verify(q, kd, vd, table, lengths, window_lens)
    fn = {"ref": ref.spec_verify, "jnp": ops.spec_verify_jnp,
          "pallas": pallas_spec}[impl]
    got = fn(q, kq, vq, table, lengths, window_lens, k_scales=ks, v_scales=vs)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL)


# ---------------------------------------------------------------------------
# scale pools move with pages (COW) + pool/table invariants
# ---------------------------------------------------------------------------
def test_copy_pages_moves_scales_with_pages():
    rng = np.random.default_rng(3)
    L, num_pages = 2, 10
    k = jnp.asarray(rng.standard_normal((L, num_pages, PAGE, KVH, DH)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((L, num_pages, PAGE, KVH, DH)), jnp.float32)
    kq, ks = kvquant.quantize(k, "int8")
    vq, vs = kvquant.quantize(v, "int8")
    src = jnp.asarray([2, 5], jnp.int32)
    dst = jnp.asarray([7, 8], jnp.int32)
    out = ops.copy_pages(kq, vq, src, dst, ks, vs)
    assert len(out) == 4
    nk, nv, nks, nvs = (np.asarray(t) for t in out)
    for s, d in ((2, 7), (5, 8)):
        np.testing.assert_array_equal(nk[:, d], np.asarray(kq)[:, s])
        np.testing.assert_array_equal(nks[:, d], np.asarray(ks)[:, s])
        np.testing.assert_array_equal(nvs[:, d], np.asarray(vs)[:, s])
    # unquantized call keeps the 2-tuple contract
    out2 = ops.copy_pages(kq, vq, src, dst)
    assert len(out2) == 2


def test_page_pool_invariants_with_scale_pool():
    """Refcount / COW / truncate / double-free invariants are dtype-blind:
    the scale pool is a parallel array indexed by the SAME page ids, so any
    page the pool hands out (or reclaims) indexes both pools consistently."""
    pool = PagePool(num_pages=12, page_size=PAGE)
    table = PageTable(num_slots=2, max_pages=4)
    # parallel physical pools: int8 payload + f32 scales, one row per page
    k_pages = np.zeros((12, PAGE, KVH, DH), np.int8)
    k_scales = np.zeros((12, PAGE, KVH), np.float32)

    a = pool.alloc(3)
    table.assign(0, a)
    for p in a:
        k_pages[p] = p          # stamp payload + scales with the page id
        k_scales[p] = float(p)
    # share page a[0] with slot 1 (prefix-cache style) and COW-split it
    pool.incref([a[0]])
    table.assign(1, [a[0]])
    assert pool.refcount(a[0]) == 2 and pool.num_shared == 1
    (priv,) = pool.alloc(1)
    k_pages[priv] = k_pages[a[0]]
    k_scales[priv] = k_scales[a[0]]
    table.replace(1, 0, priv)
    pool.free([a[0]])                         # drop slot 1's shared ref
    assert pool.refcount(a[0]) == 1           # slot 0 still holds it
    np.testing.assert_array_equal(k_scales[priv], k_scales[a[0]])

    # truncate slot 0 to one page: released page ids index BOTH pools, so
    # zeroing the released scale rows is a consistent reclaim
    released = table.truncate(0, keep=1)
    assert released == a[1:]
    pool.free(released)
    for p in released:
        k_scales[p] = 0.0
        assert pool.refcount(p) == 0
    # double-free guard covers the released (scale-carrying) pages too
    with pytest.raises(ValueError, match="double free"):
        pool.free([released[0]])
    # and slot 0's surviving page still has its scales intact
    assert float(k_scales[a[0]][0, 0]) == float(a[0])


def test_paged_cache_defs_quantized():
    cfg = get_config("glm4-9b", reduced=True)
    model = build_model(cfg)
    defs = model.paged_cache_defs(num_pages=6, page_size=PAGE, dtype="int8")
    assert set(defs) >= {"k_pages", "v_pages", "k_scales", "v_scales"}
    assert jnp.dtype(defs["k_pages"].dtype) == jnp.dtype(jnp.int8)
    L = cfg.num_layers
    assert defs["k_scales"].shape == (L, 6, PAGE, cfg.num_kv_heads)
    assert jnp.dtype(defs["k_scales"].dtype) == jnp.dtype(jnp.float32)
    # scale pools shard with the kv heads (trailing axis), like the pages
    assert defs["k_scales"].axes[-1] == defs["k_pages"].axes[-2]
    # full-precision defs carry no scale pools (bit-identical off mode)
    plain = model.paged_cache_defs(num_pages=6, page_size=PAGE, dtype="float32")
    assert set(plain) == {"k_pages", "v_pages"}


# ---------------------------------------------------------------------------
# engine: flag off == bit-identical; quantized modes agree with each other
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def _served_model():
    cfg = get_config("glm4-9b", reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _requests(cfg, shared_prefix=False):
    rng = np.random.default_rng(7)
    if shared_prefix:
        prefix = rng.integers(0, cfg.vocab_size, (8,)).astype(np.int32)
        prompts = [
            np.concatenate([prefix, rng.integers(0, cfg.vocab_size, (n,))
                            .astype(np.int32)])
            for n in (5, 3, 7, 2)
        ]
    else:
        prompts = [
            rng.integers(0, cfg.vocab_size, (n,)).astype(np.int32)
            for n in (5, 9, 13, 4)
        ]
    return [
        ServeRequest(request_id=i, prompt=p, max_new_tokens=m)
        for i, (p, m) in enumerate(zip(prompts, (6, 4, 8, 3)))
    ]


def _tokens_by_id(stats):
    return {r.request_id: r.tokens.tolist() for r in stats.results}


@pytest.mark.parametrize("prefill_mode", ["packed", "chunked"])
@pytest.mark.parametrize("spec_k", [0, 2])
@pytest.mark.parametrize("prefix_cache", [False, True])
def test_kv_dtype_off_is_bit_identical(_served_model, prefill_mode, spec_k,
                                       prefix_cache):
    """kv_dtype=None must be byte-for-byte the engine that existed before
    the flag: same pool dtypes, same launches, same greedy tokens."""
    cfg, model, params = _served_model
    kwargs = dict(
        num_slots=3, page_size=8, num_pages=40, prefill_mode=prefill_mode,
        spec_k=spec_k, prefix_cache=prefix_cache,
    )
    base = ServingEngine(model, params, max_batch=3, max_seq=64).serve_paged(
        _requests(cfg, prefix_cache), **kwargs
    )
    off = ServingEngine(
        model, params, max_batch=3, max_seq=64, kv_dtype=None
    ).serve_paged(_requests(cfg, prefix_cache), **kwargs)
    assert _tokens_by_id(off) == _tokens_by_id(base)
    assert off.kv_dtype == base.kv_dtype == "float32"


@pytest.mark.parametrize("mode", ["int8", "fp8"])
def test_quantized_cross_mode_token_identity(_served_model, mode):
    """Every serving path reads the same quantized pool through the same
    fused-dequant math, so packed == chunked prefill, spec on == off, and
    prefix-cache on == off must hold token-exactly even though quantized
    tokens may differ from full precision."""
    cfg, model, params = _served_model
    eng = ServingEngine(
        model, params, max_batch=3, max_seq=64, kv_dtype=mode
    )
    base = eng.serve_paged(
        _requests(cfg), num_slots=3, page_size=8, num_pages=40
    )
    assert base.kv_dtype == mode
    assert base.kv_bytes_per_token > 0
    chunked = eng.serve_paged(
        _requests(cfg), num_slots=3, page_size=8, num_pages=40,
        prefill_mode="chunked",
    )
    assert _tokens_by_id(chunked) == _tokens_by_id(base)
    spec = eng.serve_paged(
        _requests(cfg), num_slots=3, page_size=8, num_pages=40, spec_k=2
    )
    assert _tokens_by_id(spec) == _tokens_by_id(base)
    pfx_reqs = _requests(cfg, shared_prefix=True)
    pfx_off = eng.serve_paged(
        pfx_reqs, num_slots=3, page_size=8, num_pages=40, prefix_cache=False
    )
    pfx_on = eng.serve_paged(
        _requests(cfg, shared_prefix=True), num_slots=3, page_size=8,
        num_pages=40, prefix_cache=True,
    )
    assert _tokens_by_id(pfx_on) == _tokens_by_id(pfx_off)


def test_quantized_pool_byte_accounting(_served_model):
    cfg, model, params = _served_model
    eng = ServingEngine(model, params, max_batch=3, max_seq=64, kv_dtype="int8")
    stats = eng.serve_paged(_requests(cfg), num_slots=3, page_size=8,
                            num_pages=40)
    assert stats.kv_bytes_per_token == kvquant.kv_bytes_per_token(
        cfg.num_layers, cfg.num_kv_heads, cfg.head_dim, "int8"
    )
    full = ServingEngine(model, params, max_batch=3, max_seq=64).serve_paged(
        _requests(cfg), num_slots=3, page_size=8, num_pages=40
    )
    assert full.kv_bytes_per_token == kvquant.kv_bytes_per_token(
        cfg.num_layers, cfg.num_kv_heads, cfg.head_dim, "float32"
    )
    assert stats.kv_bytes_per_token < full.kv_bytes_per_token


def test_engine_rejects_unknown_kv_dtype(_served_model):
    cfg, model, params = _served_model
    with pytest.raises(ValueError, match="kv_dtype"):
        ServingEngine(model, params, max_batch=2, max_seq=32, kv_dtype="int4")


# ---------------------------------------------------------------------------
# divergence harness + manifest knobs + regression gating
# ---------------------------------------------------------------------------
def test_kv_divergence_summary_exact_and_diverged():
    ref_t = [[1, 2, 3, 4], [5, 6, 7], [8, 9]]
    test_t = [[1, 2, 3, 4], [5, 6, 9], [8, 9]]
    s = kv_divergence_summary(ref_t, test_t)
    assert s["requests"] == 3.0
    assert s["exact_matches"] == 2.0
    assert s["exact_match_fraction"] == pytest.approx(2 / 3)
    assert s["divergence_fraction"] == pytest.approx(1 / 3)
    assert s["first_divergence_min"] == 2.0
    assert s["first_divergence_mean"] == 2.0
    # a truncated stream diverges at its end even if the prefix matches
    s2 = kv_divergence_summary([[1, 2, 3]], [[1, 2]])
    assert s2["exact_matches"] == 0.0
    assert s2["first_divergence_min"] == 2.0
    assert kv_divergence_summary([], []) == {}
    with pytest.raises(ValueError, match="mismatched"):
        kv_divergence_summary([[1]], [[1], [2]])
    assert "exact_match_fraction" in kv_divergence_section(ref_t, test_t)
    assert kv_divergence_section([], []) == ""


def test_engine_knobs_roundtrip():
    k = EngineKnobs(engine="paged", kv_dtype="int8", page_size=16, spec_k=4,
                    prefix_cache=True, tp=2)
    again = EngineKnobs.from_dict(k.to_dict())
    assert again == k
    # unknown keys are ignored so old records stay loadable
    assert EngineKnobs.from_dict({**k.to_dict(), "extra": 1}) == k
    d = k.describe()
    assert "kv_dtype=int8" in d and "prefix_cache=on" in d and "tp=2" in d
    assert EngineKnobs().describe().startswith("engine=static kv_dtype=float32")


def _bench_json(tmp_path, name, metrics):
    p = tmp_path / name
    p.write_text(json.dumps(metrics))
    return str(p)


def test_check_regression_lower_is_better(tmp_path):
    import sys
    from pathlib import Path

    root = str(Path(__file__).resolve().parents[1])
    if root not in sys.path:
        sys.path.insert(0, root)
    from benchmarks.check_regression import main as check

    base = _bench_json(tmp_path, "base.json",
                       {"div": 0.10, "zero": 0.0, "tps": 100.0})
    # within ceiling: 0.12 <= 0.10 * 1.25
    ok = _bench_json(tmp_path, "ok.json",
                     {"div": 0.12, "zero": 0.0, "tps": 100.0})
    assert check([ok, base, "--metric-lower", "div",
                  "--metric-lower", "zero", "--metric", "tps"]) == 0
    # rises past the ceiling -> regression
    bad = _bench_json(tmp_path, "bad.json",
                      {"div": 0.2, "zero": 0.0, "tps": 100.0})
    assert check([bad, base, "--metric-lower", "div"]) == 1
    # a zero baseline is a hard gate: any rise fails
    nz = _bench_json(tmp_path, "nz.json",
                     {"div": 0.1, "zero": 0.01, "tps": 100.0})
    assert check([nz, base, "--metric-lower", "zero"]) == 1
    # higher-is-better direction unchanged
    slow = _bench_json(tmp_path, "slow.json",
                       {"div": 0.1, "zero": 0.0, "tps": 50.0})
    assert check([slow, base, "--metric", "tps"]) == 1
