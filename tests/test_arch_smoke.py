"""Per-architecture smoke tests (assignment requirement):

Instantiate the REDUCED config of every assigned architecture and run one
forward + one train step on CPU, asserting output shapes and no NaNs; also
exercise prefill/decode consistency for every family.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPES, get_config, list_archs, shape_applicable
from repro.models import build_model, count_params
from repro.train import OptimizerConfig, init_opt_state, make_train_step

ARCHS = list_archs()


def _batch(cfg, b=2, s=16, rng=None):
    rng = rng or np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)}
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(b, cfg.encoder_seq, cfg.d_model)), jnp.float32
        )
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = get_config(arch, reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    logits, aux = model.forward(params, batch)
    b, s = batch["tokens"].shape
    assert logits.shape == (b, s, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all(), f"{arch}: non-finite logits"
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step_runs_and_is_finite(arch):
    cfg = get_config(arch, reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt_cfg = OptimizerConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    opt_state = init_opt_state(model.param_defs(), opt_cfg)
    step = make_train_step(model, opt_cfg, microbatches=2, remat=True)
    batch = _batch(cfg, b=4, s=16)
    new_params, new_opt, metrics = jax.jit(step)(params, opt_state, batch)
    assert np.isfinite(float(metrics["loss"])), f"{arch}: NaN loss"
    assert np.isfinite(float(metrics["grad_norm"]))
    assert int(new_opt["step"]) == 1
    # params actually moved
    delta = sum(
        float(jnp.abs(a - b).sum())
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(new_params))
    )
    assert delta > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_consistency(arch):
    cfg = get_config(arch, reduced=True)
    if cfg.family == "moe":
        cfg = cfg.replace(capacity_factor=8.0)  # avoid capacity drops in the check
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    batch = _batch(cfg, b=2, s=12, rng=rng)
    logits, _ = model.forward(params, batch)
    cache = model.init_cache(2, 32, dtype="float32")
    last, cache = model.prefill(params, batch, cache)
    np.testing.assert_allclose(
        np.asarray(last), np.asarray(logits[:, -1]), rtol=3e-3, atol=3e-3,
        err_msg=f"{arch}: prefill != forward",
    )
    nxt = jnp.asarray(rng.integers(0, cfg.vocab_size, (2,)), jnp.int32)
    step_logits, cache = model.decode(params, nxt, cache)
    batch2 = dict(batch)
    batch2["tokens"] = jnp.concatenate([batch["tokens"], nxt[:, None]], axis=1)
    logits2, _ = model.forward(params, batch2)
    np.testing.assert_allclose(
        np.asarray(step_logits), np.asarray(logits2[:, -1]), rtol=3e-3, atol=3e-3,
        err_msg=f"{arch}: decode != forward",
    )
    assert int(cache["pos"][0]) == 13


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_matches_assignment(arch):
    """The FULL configs carry the exact published hyperparameters."""
    cfg = get_config(arch)
    cfg.validate()
    expected = {
        "zamba2-2.7b": (54, 2560, 32000),
        "qwen3-moe-30b-a3b": (48, 2048, 151936),
        "llama4-maverick-400b-a17b": (48, 5120, 202048),
        "deepseek-67b": (95, 8192, 102400),
        "granite-20b": (52, 6144, 49152),
        "glm4-9b": (40, 4096, 151552),
        "gemma2-27b": (46, 4608, 256000),
        "chameleon-34b": (48, 8192, 65536),
        "mamba2-130m": (24, 768, 50280),
        "whisper-large-v3": (32, 1280, 51866),
    }[arch]
    assert (cfg.num_layers, cfg.d_model, cfg.vocab_size) == expected


def test_long_500k_applicability_matches_design():
    runs = {a for a in ARCHS if shape_applicable(get_config(a), SHAPES["long_500k"])[0]}
    assert runs == {"zamba2-2.7b", "mamba2-130m"}


def test_param_counts_near_labels():
    cases = {
        "deepseek-67b": (67e9, 0.02),
        "glm4-9b": (9.4e9, 0.1),
        "gemma2-27b": (27e9, 0.05),
        "chameleon-34b": (34e9, 0.05),
        "mamba2-130m": (130e6, 0.1),
        "qwen3-moe-30b-a3b": (30.5e9, 0.05),
        "llama4-maverick-400b-a17b": (400e9, 0.05),
    }
    for arch, (target, tol) in cases.items():
        n = get_config(arch).param_count()
        assert abs(n - target) / target < tol + 0.05, f"{arch}: {n:.3g} vs {target:.3g}"


def test_hybrid_ring_cache_decode_long_context():
    """Zamba2-style ring cache: decode far past the window stays finite and
    the ring slot invariant (slot = pos % window) holds."""
    cfg = get_config("zamba2-2.7b", reduced=True).replace(long_context_window=8)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(2)
    # cache sized at the ring window (the long_500k path)
    cache = model.init_cache(1, 100_000, dtype="float32")
    assert cache["k"].shape[2] == cfg.long_context_window
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 20)), jnp.int32)}
    _, cache = model.prefill(params, batch, cache)
    for _ in range(12):
        tok = jnp.asarray(rng.integers(0, cfg.vocab_size, (1,)), jnp.int32)
        logits, cache = model.decode(params, tok, cache)
        assert np.isfinite(np.asarray(logits)).all()
    assert int(cache["pos"][0]) == 32
