"""Serving engine + sharding-rule unit tests."""
from types import SimpleNamespace

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec

from repro.configs import get_config
from repro.models import build_model
from repro.models.params import P
from repro.serve.engine import ServingEngine
from repro.sharding.specs import ShardingRules, default_rules, param_pspecs


# ---------------------------------------------------------------------------
# Serving engine
# ---------------------------------------------------------------------------
def test_engine_generate_matches_stepwise_forward():
    cfg = get_config("glm4-9b", reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServingEngine(model, params, max_batch=2, max_seq=32)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, (7,)).astype(np.int32) for _ in range(2)]
    res = engine.generate(prompts, max_new_tokens=4)
    assert res.tokens.shape == (2, 4)
    assert res.tokens_per_s > 0
    # greedy check against explicit forward for row 0 first new token
    batch = {"tokens": jnp.asarray(np.stack(prompts))}
    logits, _ = model.forward(params, batch)
    expected_first = int(jnp.argmax(logits[0, -1]))
    assert int(res.tokens[0, 0]) == expected_first


def test_engine_continuous_batching_slot_reuse():
    """A queued prompt is admitted into the slot freed by a finished
    sequence, at a decode-step boundary (fake clock: no real sleeps)."""
    from repro.serve.engine import ServeRequest

    cfg = get_config("glm4-9b", reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServingEngine(model, params, max_batch=2, max_seq=32)
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab_size, (5,)).astype(np.int32) for _ in range(3)]
    reqs = [
        ServeRequest(request_id=0, prompt=prompts[0], max_new_tokens=2),
        ServeRequest(request_id=1, prompt=prompts[1], max_new_tokens=6),
        ServeRequest(request_id=2, prompt=prompts[2], max_new_tokens=3),
    ]

    class VT:
        t = 0.0

        def clock(self):
            self.t += 1.0
            return self.t

    stats = engine.serve_continuous(reqs, num_slots=2, clock=VT().clock)
    by_id = {r.request_id: r for r in stats.results}
    # requests 0 and 1 are admitted immediately; 2 waits for a free slot
    assert by_id[0].admit_step == 0 and by_id[1].admit_step == 0
    assert by_id[2].admit_step == by_id[0].finish_step  # admitted when 0 frees
    assert by_id[2].admit_step > 0
    assert by_id[2].slot == by_id[0].slot               # the freed slot is reused
    for r in stats.results:
        assert len(r.tokens) == reqs[r.request_id].max_new_tokens
        assert r.ttft_s > 0 and r.latency_s >= r.ttft_s
    assert stats.total_tokens == 2 + 6 + 3
    assert 1.0 <= stats.mean_slot_occupancy <= 2.0


def test_engine_continuous_single_token_budget():
    """A request whose whole budget is the prefill token retires without a
    decode step appending a spurious second token."""
    from repro.serve.engine import ServeRequest

    cfg = get_config("glm4-9b", reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServingEngine(model, params, max_batch=2, max_seq=32)
    prompt = np.arange(4, dtype=np.int32)
    stats = engine.serve_continuous(
        [ServeRequest(request_id=0, prompt=prompt, max_new_tokens=1)], num_slots=2
    )
    assert len(stats.results[0].tokens) == 1
    assert stats.total_tokens == 1


def test_engine_continuous_rejects_encdec():
    from repro.serve.engine import ServeRequest

    cfg = get_config("whisper-large-v3", reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServingEngine(model, params, max_batch=2, max_seq=32)
    with pytest.raises(NotImplementedError, match="encoder-decoder"):
        engine.serve_continuous(
            [ServeRequest(request_id=0, prompt=np.arange(4, dtype=np.int32),
                          max_new_tokens=2)]
        )


def test_engine_continuous_matches_static_generate():
    """Greedy tokens from the continuous path equal the static batched path
    (same left-padding, masked vs uniform cache writes are equivalent)."""
    from repro.serve.engine import ServeRequest

    cfg = get_config("glm4-9b", reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServingEngine(model, params, max_batch=2, max_seq=32)
    rng = np.random.default_rng(2)
    prompts = [rng.integers(0, cfg.vocab_size, (6,)).astype(np.int32) for _ in range(2)]
    static = engine.generate(prompts, max_new_tokens=4)
    reqs = [
        ServeRequest(request_id=i, prompt=p, max_new_tokens=4)
        for i, p in enumerate(prompts)
    ]
    cont = engine.serve_continuous(reqs, num_slots=2)
    for i, r in enumerate(cont.results):
        np.testing.assert_array_equal(r.tokens, static.tokens[i])


def test_engine_paged_matches_continuous():
    """serve_paged (chunked prefill + paged KV + Pallas-style page tables)
    emits exactly the tokens of serve_continuous for the same seeded
    requests — the paged layout is bit-compatible with the dense path."""
    from repro.serve.engine import ServeRequest

    cfg = get_config("glm4-9b", reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServingEngine(model, params, max_batch=3, max_seq=32)
    rng = np.random.default_rng(7)
    prompts = [
        rng.integers(0, cfg.vocab_size, (n,)).astype(np.int32) for n in (5, 9, 7, 4)
    ]
    reqs = lambda: [
        ServeRequest(request_id=i, prompt=p, max_new_tokens=m)
        for i, (p, m) in enumerate(zip(prompts, (6, 4, 8, 3)))
    ]
    cont = engine.serve_continuous(reqs(), num_slots=2)
    paged = engine.serve_paged(
        reqs(), num_slots=3, page_size=4, prefill_chunk=8
    )
    by_id = {r.request_id: r for r in cont.results}
    for r in paged.results:
        np.testing.assert_array_equal(r.tokens, by_id[r.request_id].tokens)
    assert paged.total_tokens == cont.total_tokens == 6 + 4 + 8 + 3
    assert paged.prefill_chunks >= len(prompts)  # every prompt chunk-prefilled
    assert paged.preemptions == 0                # default admission reserves


def test_engine_paged_preemption_under_page_pressure():
    """With an overcommitted pool the youngest request is preempted
    (recompute-style) and still finishes with identical greedy tokens."""
    from repro.serve.engine import ServeRequest

    cfg = get_config("glm4-9b", reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServingEngine(model, params, max_batch=3, max_seq=32)
    rng = np.random.default_rng(3)
    prompts = [
        rng.integers(0, cfg.vocab_size, (n,)).astype(np.int32) for n in (9, 8, 7, 5)
    ]
    reqs = lambda: [
        ServeRequest(request_id=i, prompt=p, max_new_tokens=m)
        for i, (p, m) in enumerate(zip(prompts, (10, 8, 12, 6)))
    ]
    cont = engine.serve_continuous(reqs(), num_slots=2)
    # 6 allocatable pages of 4 tokens = 24 live tokens; worst case needs 19
    # per request, so overcommitted admission forces page-pressure evictions
    paged = engine.serve_paged(
        reqs(), num_slots=3, page_size=4, num_pages=7, prefill_chunk=4,
        overcommit=10.0,
    )
    assert paged.preemptions > 0
    by_id = {r.request_id: r for r in cont.results}
    for r in paged.results:
        np.testing.assert_array_equal(r.tokens, by_id[r.request_id].tokens)
    assert paged.peak_pages_in_use <= paged.num_pages == 6


def test_engine_prefill_bucketing_bounds_compiles():
    """Distinct prompt lengths map to one power-of-two prefill bucket, so
    the engine stops recompiling per length (counted in compile stats)."""
    cfg = get_config("glm4-9b", reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServingEngine(model, params, max_batch=2, max_seq=64)
    rng = np.random.default_rng(0)
    first = None
    for n in (3, 5, 9, 14):     # all bucket to 16 (floor page_size=16)
        p = rng.integers(0, cfg.vocab_size, (n,)).astype(np.int32)
        res = engine.generate([p], max_new_tokens=2)
        # bucketing must stay numerically exact: right-padding + causal
        # attention means the first token matches the unpadded forward
        logits, _ = model.forward(params, {"tokens": jnp.asarray(p[None])})
        assert int(res.tokens[0, 0]) == int(jnp.argmax(logits[0, -1]))
        if first is None:
            first = engine.compile_stats()["prefill"]
    stats = engine.compile_stats()
    assert stats["prefill"] == first == 1
    assert stats["decode"] >= 1


def test_page_pool_and_table_bookkeeping():
    from repro.serve.page_table import PagePool, PageTable, pages_needed

    assert pages_needed(0, 8) == 0
    assert pages_needed(1, 8) == 1
    assert pages_needed(17, 8) == 3
    pool = PagePool(6, 8, reserved=1)    # pages 1..5 allocatable
    assert pool.capacity == 5
    a = pool.alloc(3)
    assert sorted(a) == [1, 2, 3] and pool.num_in_use == 3
    assert pool.alloc(3) is None         # atomic: all-or-nothing
    b = pool.alloc(2)
    assert pool.num_free == 0 and pool.peak_in_use == 5
    pool.free(a)
    with pytest.raises(ValueError):
        pool.free([a[0]])                # double free
    table = PageTable(2, 4)
    table.assign(0, b)
    with pytest.raises(ValueError):
        table.assign(0, [1])             # slot already holds pages
    table.append(0, 1)
    assert table.num_pages_of(0) == 3
    mask = np.array([False, True])
    assert (table.rows_for(mask)[0] == 0).all()  # masked row -> scratch page
    assert table.clear(0) == b + [1]
    assert table.num_pages_of(0) == 0


def test_engine_rejects_oversize():
    cfg = get_config("glm4-9b", reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServingEngine(model, params, max_batch=1, max_seq=8)
    with pytest.raises(ValueError):
        engine.generate([np.zeros(4, np.int32)] * 2, max_new_tokens=1)
    with pytest.raises(ValueError):
        engine.generate([np.zeros(7, np.int32)], max_new_tokens=5)


# ---------------------------------------------------------------------------
# Sharding rules (pure spec logic — uses a stub mesh, no devices needed)
# ---------------------------------------------------------------------------
def _stub_mesh(shape_dict):
    return SimpleNamespace(shape=shape_dict, axis_names=tuple(shape_dict))


def _norm(spec):
    """Normalize PartitionSpec entries: 'x' and ('x',) are the same sharding
    (older jax canonicalized these as equal; newer versions compare raw)."""
    return tuple(
        None if e is None else ((e,) if isinstance(e, str) else tuple(e))
        for e in spec
    )


def test_divisibility_fallback():
    mesh = _stub_mesh({"data": 16, "model": 16})
    rules = default_rules(mesh)
    # divisible: sharded
    assert rules.mesh_axes_for("heads", 32) == "model"
    # not divisible: dropped to replication
    assert rules.mesh_axes_for("heads", 20) is None
    assert rules.mesh_axes_for("vocab", 50280) is None
    assert rules.mesh_axes_for("vocab", 102400) == "model"
    # batch composes pod+data when present
    mesh3 = _stub_mesh({"pod": 2, "data": 16, "model": 16})
    rules3 = default_rules(mesh3)
    assert rules3.mesh_axes_for("batch", 256) == ("pod", "data")
    assert rules3.mesh_axes_for("batch", 16) == "pod"  # drops trailing axes
    assert rules3.mesh_axes_for("batch", 1) is None


def test_param_pspecs_from_logical_axes():
    mesh = _stub_mesh({"data": 16, "model": 16})
    rules = default_rules(mesh, fsdp=True)
    defs = {
        "wq": P((4, 8192, 64, 128), axes=("layer", "embed", "heads", "head_dim")),
        "norm": P((8192,), axes=("embed",)),
    }
    specs = param_pspecs(defs, rules)
    assert _norm(specs["wq"]) == _norm(PartitionSpec(None, ("data",), "model", None))
    # fsdp shards norm's embed dim over data
    assert _norm(specs["norm"]) == _norm(PartitionSpec(("data",)))
    rules_nofsdp = default_rules(mesh, fsdp=False)
    specs2 = param_pspecs(defs, rules_nofsdp)
    assert _norm(specs2["wq"]) == _norm(PartitionSpec(None, None, "model", None))


def test_moe_expert_specs_no_duplicate_axes():
    mesh = _stub_mesh({"data": 16, "model": 16})
    rules = default_rules(mesh, fsdp=True)
    defs = {
        "w_gate": P((24, 128, 5120, 8192),
                    axes=("layer", "experts", "embed", "expert_ffn")),
    }
    spec = param_pspecs(defs, rules)["w_gate"]
    assert _norm(spec) == _norm(PartitionSpec(None, "model", ("data",), None))
    flat = [a for dim in spec for a in ((dim,) if isinstance(dim, str) else (dim or ()))]
    assert len(flat) == len(set(flat))  # no mesh axis used twice


def test_rank_mismatch_raises():
    mesh = _stub_mesh({"data": 2, "model": 2})
    rules = default_rules(mesh)
    with pytest.raises(ValueError):
        param_pspecs({"bad": P((2, 2), axes=("embed",))}, rules)
