"""Automatic prefix caching: refcounted page-pool invariants, the
hash-chained PrefixCache (lookup/insert/LRU eviction, never reclaiming a
referenced page), copy-on-write before any append into a shared page,
greedy bit-identity of ``prefix_cache=on`` vs ``off`` across packed/chunked
prefill and spec_k > 0 (incl. preemption of cache-hit requests), the exact
admitted = computed + saved + dropped prompt-token ledger, shared-prefix
workload generators, and the prefix-cache analysis section."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.analysis import prefix_cache_section, prefix_cache_summary
from repro.core.tracing import Span, Tracer, TraceLevel, TracingServer
from repro.core.workload import (
    SharedPrefixLoad,
    make_generator,
    shared_prefix_prompts,
)
from repro.models import build_model
from repro.serve.engine import ServeRequest, ServingEngine
from repro.serve.page_table import PagePool, PageTable, PrefixCache


# ---------------------------------------------------------------------------
# PagePool refcounts
# ---------------------------------------------------------------------------
def test_pool_refcount_alloc_incref_free():
    pool = PagePool(num_pages=8, page_size=4)
    pages = pool.alloc(3)
    assert all(pool.refcount(p) == 1 for p in pages)
    pool.incref(pages[:2])
    assert pool.refcount(pages[0]) == 2 and pool.refcount(pages[2]) == 1
    assert pool.num_shared == 2
    # first free of a shared page only drops the count — nothing released
    released = pool.free(pages[:2])
    assert released == []
    assert pool.num_in_use == 3 and pool.num_shared == 0
    # second free really releases
    released = pool.free(pages)
    assert sorted(released) == sorted(pages)
    assert pool.num_free == pool.capacity


def test_pool_double_free_guard_is_refcount_aware():
    pool = PagePool(num_pages=6, page_size=4)
    (p,) = pool.alloc(1)
    pool.incref([p])
    pool.free([p])
    pool.free([p])              # second reference: legitimate
    with pytest.raises(ValueError, match="double free"):
        pool.free([p])          # third: one more than ever referenced
    with pytest.raises(ValueError, match="incref on free page"):
        pool.incref([p])
    with pytest.raises(ValueError, match="not allocated"):
        pool.free([99])


def test_page_table_replace_remaps_one_logical_page():
    table = PageTable(num_slots=2, max_pages=4)
    table.assign(0, [5, 6, 7])
    old = table.replace(0, 1, 9)
    assert old == 6
    assert table.pages_of(0) == [5, 9, 7]
    assert table.table[0, 1] == 9
    with pytest.raises(ValueError, match="no logical page"):
        table.replace(0, 3, 2)


def test_truncate_on_shared_pages_keeps_other_holders():
    """Spec-decode rollback on a slot holding cache-shared pages: the
    truncated pages drop only this holder's reference — the cache (or
    another request) keeps the page alive."""
    pool = PagePool(num_pages=8, page_size=4)
    table = PageTable(num_slots=1, max_pages=4)
    pages = pool.alloc(3)
    pool.incref(pages[2:])              # someone else also maps the last page
    table.assign(0, pages)
    freed = table.truncate(0, 2)
    assert freed == [pages[2]]
    assert pool.free(freed) == []       # shared: not actually released
    assert pool.refcount(pages[2]) == 1


# ---------------------------------------------------------------------------
# PrefixCache
# ---------------------------------------------------------------------------
def _prompt(*blocks):
    return np.concatenate([np.asarray(b, np.int32) for b in blocks])


def test_prefix_cache_lookup_longest_chain():
    pool = PagePool(num_pages=16, page_size=4)
    cache = PrefixCache(pool)
    b0, b1, b2 = [1, 2, 3, 4], [5, 6, 7, 8], [9, 10, 11, 12]
    pages = pool.alloc(3)
    cache.insert(_prompt(b0, b1, b2), pages)
    assert all(pool.refcount(p) == 2 for p in pages)   # cache's own refs

    hit, cached = cache.lookup(_prompt(b0, b1, [9, 9, 9, 9], [1]))
    assert hit == pages[:2] and cached == 8            # diverges at block 2
    hit, cached = cache.lookup(_prompt(b0, b1, b2))
    assert hit == pages and cached == 12               # full page-aligned hit
    hit, cached = cache.lookup(_prompt([7, 7, 7, 7]))
    assert hit == [] and cached == 0                   # content-keyed: no hit
    # a matching block NOT reached through the chain is invisible
    hit, cached = cache.lookup(_prompt(b1, b2))
    assert hit == []
    # partial last pages are never cached
    hit, cached = cache.lookup(_prompt(b0, [5, 6]))
    assert hit == pages[:1] and cached == 4
    s = cache.stats()
    assert s["lookups"] == 5.0 and s["hits"] == 3.0 and s["full_hits"] == 1.0


def test_prefix_cache_eviction_lru_leaf_first_never_referenced():
    pool = PagePool(num_pages=16, page_size=4)
    cache = PrefixCache(pool)
    b0, b1 = [1, 2, 3, 4], [5, 6, 7, 8]
    c0, c1 = [9, 9, 9, 9], [8, 8, 8, 8]
    chain_a = pool.alloc(2)
    chain_b = pool.alloc(2)
    cache.insert(_prompt(b0, b1), chain_a)
    cache.insert(_prompt(c0, c1), chain_b)
    pool.free(chain_a)                  # requests release: cache-only refs
    pool.free(chain_b)
    assert cache.evictable == 4
    cache.lookup(_prompt(b0, b1))       # chain A is now most recent
    # leaf-first in LRU order: chain B's leaf goes before its root, and all
    # of B goes before any of A
    assert cache.evict(1) == 1
    assert pool.refcount(chain_b[1]) == 0
    assert pool.refcount(chain_b[0]) == 1
    assert cache.evict(10) == 3          # drains B root then A leaf-first
    assert len(cache) == 0 and pool.num_free == pool.capacity


def test_prefix_cache_eviction_skips_referenced_pages():
    pool = PagePool(num_pages=16, page_size=4)
    cache = PrefixCache(pool)
    b0, b1 = [1, 2, 3, 4], [5, 6, 7, 8]
    pages = pool.alloc(2)
    cache.insert(_prompt(b0, b1), pages)
    # a request still maps both pages: nothing is evictable
    assert cache.evictable == 0
    assert cache.evict(5) == 0
    assert pool.refcount(pages[0]) == 2
    pool.free(pages)                    # request releases
    assert cache.evict(5) == 2
    assert cache.stats()["evicted_pages"] == 2.0


def test_prefix_cache_insert_is_first_writer_wins():
    pool = PagePool(num_pages=16, page_size=4)
    cache = PrefixCache(pool)
    b0 = [1, 2, 3, 4]
    first = pool.alloc(1)
    second = pool.alloc(1)
    assert cache.insert(_prompt(b0), first) == 1
    assert cache.insert(_prompt(b0), second) == 0      # duplicate content
    hit, _ = cache.lookup(_prompt(b0))
    assert hit == first
    assert pool.refcount(second[0]) == 1               # newcomer stays private


# ---------------------------------------------------------------------------
# Serving pipeline: bit-identity, COW, eviction, preemption, ledger
# ---------------------------------------------------------------------------
def _engine(max_seq=96, num_slots=4):
    cfg = get_config("glm4-9b", reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, ServingEngine(model, params, max_batch=num_slots, max_seq=max_seq)


def _shared_reqs(cfg, rng, page=8, n=8, gen=5):
    """Mixed workload: shared 3-page prefix + unique tails, plus verbatim
    page-aligned repeats (full hits -> COW)."""
    prefix = rng.integers(0, cfg.vocab_size, (3 * page,)).astype(np.int32)
    prompts = []
    for i in range(n):
        if i % 3 == 2:
            prompts.append(prefix.copy())
        else:
            tail = rng.integers(0, cfg.vocab_size, (5,)).astype(np.int32)
            prompts.append(np.concatenate([prefix, tail]))
    return lambda: [
        ServeRequest(request_id=i, prompt=p, max_new_tokens=gen)
        for i, p in enumerate(prompts)
    ]


def _ledger_exact(stats):
    assert stats.prompt_tokens_admitted == (
        stats.prefill_tokens + stats.saved_prefill_tokens
        + stats.prefill_tokens_dropped
    )


@pytest.mark.parametrize("prefill_mode", ["packed", "chunked"])
@pytest.mark.parametrize("spec_k", [0, 2])
def test_prefix_cache_bit_identical(prefill_mode, spec_k):
    """Greedy tokens with the cache on are bit-identical to cache-off in
    every prefill pipeline, with and without speculative decoding — and the
    cache genuinely fires (hits, full hits and COW copies all non-zero)."""
    cfg, engine = _engine()
    reqs = _shared_reqs(cfg, np.random.default_rng(11))
    kw = dict(num_slots=4, page_size=8, prefill_mode=prefill_mode,
              spec_k=spec_k, prefill_chunk=16, prefill_budget=32)
    off = engine.serve_paged(reqs(), **kw)
    on = engine.serve_paged(reqs(), prefix_cache=True, **kw)
    by_id = {r.request_id: r for r in off.results}
    for r in on.results:
        np.testing.assert_array_equal(r.tokens, by_id[r.request_id].tokens)
    assert on.prefix_cache and not off.prefix_cache
    assert on.prefix_stats["hits"] > 0
    assert on.prefix_stats["full_hits"] > 0
    assert on.cow_copies > 0                 # full hits split their last page
    assert on.saved_prefill_tokens > 0
    assert on.prefill_tokens < off.prefill_tokens
    _ledger_exact(on)
    _ledger_exact(off)
    assert off.saved_prefill_tokens == 0
    assert off.prefix_stats == {}


def test_prefix_cache_accounting_and_budget_credit():
    """Cached tokens are zero-cost to the PrefillBudget ledger (credited,
    never granted) and the saved-token split is exact per path: computed +
    saved covers every admitted prompt token."""
    cfg, engine = _engine()
    reqs = _shared_reqs(cfg, np.random.default_rng(3))
    on = engine.serve_paged(reqs(), num_slots=4, page_size=8,
                            prefill_budget=32, prefix_cache=True)
    _ledger_exact(on)
    assert on.prefill_tokens_dropped == 0    # no preemption here
    b = on.prefill_budget_stats
    # every cache-served prompt token — partial-hit prefixes and full-hit
    # decode replays alike — is credited to the budget as zero-cost
    assert b["cached_tokens"] == on.saved_prefill_tokens > 0
    assert b["granted_tokens"] == on.prefill_tokens
    # saved tokens really skipped compute: granted + saved == admitted
    assert b["granted_tokens"] + on.saved_prefill_tokens == \
        on.prompt_tokens_admitted


def test_prefix_cache_ttft_collapses_on_full_hit():
    """A full hit skips prefill outright: its TTFT is a decode boundary,
    and the request's first token still matches the cache-off run."""
    cfg, engine = _engine()
    rng = np.random.default_rng(9)
    prompt = rng.integers(0, cfg.vocab_size, (16,)).astype(np.int32)
    reqs = lambda: [
        ServeRequest(request_id=i, prompt=prompt.copy(), max_new_tokens=4)
        for i in range(3)
    ]
    # one slot: requests run strictly one after another, so the second and
    # third fully hit the first's cached pages
    kw = dict(num_slots=1, page_size=8)
    off = engine.serve_paged(reqs(), **kw)
    on = engine.serve_paged(reqs(), prefix_cache=True, **kw)
    by_id = {r.request_id: r for r in off.results}
    for r in on.results:
        np.testing.assert_array_equal(r.tokens, by_id[r.request_id].tokens)
    assert on.prefix_stats["full_hits"] == 2.0
    assert on.cow_copies == 2
    assert on.prefill_tokens == 16           # only the first request prefills
    _ledger_exact(on)


def test_prefix_cache_eviction_under_pressure_never_referenced():
    """A pool too small to cache every distinct prompt forces LRU eviction
    (true frees) — admission recycles cached-unreferenced pages instead of
    failing, tokens stay correct, and the pool reconciles exactly."""
    cfg, engine = _engine(max_seq=64)
    rng = np.random.default_rng(21)
    prompts = [rng.integers(0, cfg.vocab_size, (24,)).astype(np.int32)
               for _ in range(6)]
    reqs = lambda: [
        ServeRequest(request_id=i, prompt=p, max_new_tokens=4)
        for i, p in enumerate(prompts)
    ]
    # 13 usable pages; each distinct request needs 4 — the cache fills after
    # ~3 requests and later admissions must evict stale entries
    kw = dict(num_slots=2, page_size=8, num_pages=14)
    off = engine.serve_paged(reqs(), **kw)
    on = engine.serve_paged(reqs(), prefix_cache=True, **kw)
    by_id = {r.request_id: r for r in off.results}
    for r in on.results:
        np.testing.assert_array_equal(r.tokens, by_id[r.request_id].tokens)
    assert on.cache_evictions > 0
    assert on.prefix_stats["evicted_pages"] == float(on.cache_evictions)
    assert on.peak_pages_in_use <= on.num_pages
    _ledger_exact(on)


def test_prefix_cache_preemption_of_hit_request():
    """Preempting a request that was admitted on a cache hit releases its
    shared references (never double-frees), and the recompute-style restart
    re-hits the cache — greedy tokens still match the cache-off run and the
    dropped-token ledger stays exact."""
    cfg, engine = _engine(max_seq=48)
    rng = np.random.default_rng(17)
    prefix = rng.integers(0, cfg.vocab_size, (8,)).astype(np.int32)
    prompts = [
        np.concatenate([prefix, rng.integers(0, cfg.vocab_size, (3,)).astype(np.int32)])
        for _ in range(4)
    ]
    reqs = lambda: [
        ServeRequest(request_id=i, prompt=p, max_new_tokens=m)
        for i, (p, m) in enumerate(zip(prompts, (10, 8, 12, 6)))
    ]
    kw = dict(num_slots=3, page_size=4, num_pages=13, overcommit=10.0,
              prefill_budget=8)
    off = engine.serve_paged(reqs(), **kw)
    on = engine.serve_paged(reqs(), prefix_cache=True, **kw)
    assert on.preemptions > 0
    by_id = {r.request_id: r for r in off.results}
    for r in on.results:
        np.testing.assert_array_equal(r.tokens, by_id[r.request_id].tokens)
    _ledger_exact(on)
    _ledger_exact(off)


def test_cache_off_ledger_exact_under_preemption():
    """The counter split is exact with the cache off too: every admitted
    prompt token is either computed or dropped by preemption (saved == 0)."""
    cfg, engine = _engine(max_seq=32)
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab_size, (n,)).astype(np.int32)
               for n in (9, 8, 7, 5)]
    reqs = [ServeRequest(request_id=i, prompt=p, max_new_tokens=m)
            for i, (p, m) in enumerate(zip(prompts, (10, 8, 12, 6)))]
    stats = engine.serve_paged(reqs, num_slots=3, page_size=4, num_pages=7,
                               prefill_chunk=4, overcommit=10.0)
    assert stats.preemptions > 0
    assert stats.saved_prefill_tokens == 0
    assert stats.prompt_tokens_admitted > sum(len(p) for p in prompts)
    _ledger_exact(stats)


def test_prefix_cache_emits_trace_events():
    cfg, engine = _engine()
    reqs = _shared_reqs(cfg, np.random.default_rng(11), n=6)
    server = TracingServer()
    tracer = Tracer("t", server)
    stats = engine.serve_paged(reqs(), num_slots=2, page_size=8,
                               prefix_cache=True, tracer=tracer)
    summary = prefix_cache_summary(server.timeline("t"))
    assert summary["lookups"] == stats.prefix_stats["lookups"]
    assert summary["hits"] == stats.prefix_stats["hits"]
    assert summary["saved_prefill_tokens"] == float(stats.saved_prefill_tokens)
    assert summary["cow_copies"] == float(stats.cow_copies)


# ---------------------------------------------------------------------------
# Analysis section
# ---------------------------------------------------------------------------
def _lookup_span(**tags):
    return Span(name="prefix:lookup", level=TraceLevel.SYSTEM, trace_id="t",
                tags=tags)


def test_prefix_cache_summary_and_section():
    spans = [
        _lookup_span(prompt_tokens=40, cached_tokens=32, hit_pages=4, full_hit=0),
        _lookup_span(prompt_tokens=32, cached_tokens=32, hit_pages=4, full_hit=1),
        _lookup_span(prompt_tokens=40, cached_tokens=0, hit_pages=0, full_hit=0),
        Span(name="prefix:cow", level=TraceLevel.SYSTEM, trace_id="t"),
        Span(name="prefix:evict", level=TraceLevel.SYSTEM, trace_id="t",
             tags={"pages": 3}),
    ]
    s = prefix_cache_summary(spans)
    assert s["lookups"] == 3.0 and s["hits"] == 2.0 and s["full_hits"] == 1.0
    assert s["hit_rate"] == pytest.approx(2 / 3)
    assert s["saved_prefill_tokens"] == 64.0
    assert s["saved_fraction"] == pytest.approx(64 / 112)
    assert s["cow_copies"] == 1.0 and s["evicted_pages"] == 3.0
    section = prefix_cache_section(spans)
    assert "hit_rate" in section and "saved_prefill_tokens" in section
    assert prefix_cache_section([]) == ""


# ---------------------------------------------------------------------------
# Shared-prefix workload generators
# ---------------------------------------------------------------------------
def test_shared_prefix_load_tags_and_registry():
    load = make_generator("shared_prefix", num_requests=40, prefix_len=32,
                          suffix_len=8, share_ratio=0.7, num_groups=2, seed=0)
    assert isinstance(load, SharedPrefixLoad)
    reqs = list(load.requests())
    assert len(reqs) == 40
    shared = [r for r in reqs if r.tags["prefix_group"] >= 0]
    unique = [r for r in reqs if r.tags["prefix_group"] < 0]
    assert shared and unique
    assert 0.4 <= len(shared) / len(reqs) <= 0.95
    assert all(r.tags["prefix_len"] == 32 for r in shared)
    assert all(r.tags["prefix_len"] == 0 for r in unique)
    assert all(r.tags["prompt_len"] == 40 for r in reqs)
    assert all(r.tags["prefix_group"] in (0, 1) for r in shared)
    # same seed -> same mix
    again = list(SharedPrefixLoad(40, prefix_len=32, suffix_len=8,
                                  share_ratio=0.7, num_groups=2, seed=0).requests())
    assert [r.tags for r in again] == [r.tags for r in reqs]


def test_shared_prefix_prompts_share_tokens_bit_for_bit():
    load = SharedPrefixLoad(24, prefix_len=16, suffix_len=4, share_ratio=0.8,
                            num_groups=2, seed=1)
    reqs = list(load.requests())
    prompts = shared_prefix_prompts(reqs, vocab_size=1000, seed=1)
    assert all(len(p) == 20 for p in prompts)
    by_group = {}
    for r, p in zip(reqs, prompts):
        g = r.tags["prefix_group"]
        if g >= 0:
            by_group.setdefault(g, []).append(p)
    for g, ps in by_group.items():
        for p in ps[1:]:
            np.testing.assert_array_equal(p[:16], ps[0][:16])
    assert len(by_group) == 2
    # distinct groups do NOT share their prefix
    g0, g1 = by_group[0][0], by_group[1][0]
    assert not np.array_equal(g0[:16], g1[:16])
