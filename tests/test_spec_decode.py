"""Speculative decoding: verify-kernel sweeps vs the ``ref.spec_verify``
oracle, greedy bit-identity of the spec serving path vs the non-speculative
engines (incl. preemption and mid-draft rejection rollback), the draft
acceptance ledger, ITL recording, and per-(config, k) compile accounting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.analysis import (
    itl_summary,
    spec_decode_section,
    spec_decode_summary,
)
from repro.core.tracing import Span, TraceLevel
from repro.kernels import ops, ref
from repro.kernels.spec_verify import spec_verify as pallas_spec
from repro.models import build_model
from repro.serve.engine import ServeRequest, ServingEngine, ngram_propose
from repro.serve.page_table import PageTable
from repro.serve.scheduler import SpecLedger

_RNG = np.random.default_rng(42)

PAGE = 8


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=5e-5, atol=5e-5)


def _windows(rows, W, kvh=2, h=4, d=16, max_pages=6, num_pages=32,
             dtype=jnp.float32):
    """Build a spec-verify workload: ``rows`` is a list of (committed_len,
    window_len); each row's pages cover committed + in-flight tokens (the
    engine scatters the window's K/V before attending), window starts are
    NOT page-aligned."""
    b = len(rows)
    lens = np.array([r[0] for r in rows], np.int32)
    wlens = np.array([r[1] for r in rows], np.int32)
    tables = np.zeros((b, max_pages), np.int32)
    nxt = 1
    for i, (L, wl) in enumerate(rows):
        npg = (L + wl + PAGE - 1) // PAGE
        for j in range(npg):
            tables[i, j] = nxt
            nxt += 1
    assert nxt <= num_pages and wlens.max(initial=0) <= W
    mk = lambda shape: jnp.asarray(_RNG.normal(size=shape), dtype)
    return (
        mk((b, W, h, d)),
        mk((num_pages, PAGE, kvh, d)), mk((num_pages, PAGE, kvh, d)),
        jnp.asarray(tables), jnp.asarray(lens), jnp.asarray(wlens),
    )


CASES = [
    # (rows [(committed, window_len)], W): ragged window lens, page-boundary
    # straddles (committed % PAGE != 0), fresh-page windows, idle rows
    ([(13, 4), (7, 2), (0, 0)], 4),
    ([(15, 3), (8, 1)], 3),            # window opens a brand-new page
    ([(5, 5), (22, 1), (11, 3)], 5),
    ([(0, 2)], 2),                     # no committed context at all
]


@pytest.mark.parametrize("rows,W", CASES)
@pytest.mark.parametrize("window", [None, 5])
def test_spec_jnp_vs_oracle(rows, W, window):
    args = _windows(rows, W)
    a = ref.spec_verify(*args, window=window)
    f = ops.spec_verify_jnp(*args, window=window)
    np.testing.assert_allclose(
        np.asarray(a, np.float32), np.asarray(f, np.float32), **_tol(jnp.float32)
    )


@pytest.mark.parametrize("rows,W", CASES)
@pytest.mark.parametrize("window", [None, 5])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pallas_spec_vs_oracle(rows, W, window, dtype):
    args = _windows(rows, W, dtype=dtype)
    a = ref.spec_verify(*args, window=window)
    p = pallas_spec(*args, window=window)
    assert p.dtype == args[0].dtype
    np.testing.assert_allclose(
        np.asarray(a, np.float32), np.asarray(p, np.float32), **_tol(dtype)
    )


def test_spec_softcap_and_dispatch():
    args = _windows([(9, 3), (4, 2)], 3)
    a = ref.spec_verify(*args, softcap=11.0)
    f = ops.spec_verify(*args, softcap=11.0, backend="flash")
    p = ops.spec_verify(*args, softcap=11.0, backend="pallas")
    np.testing.assert_allclose(
        np.asarray(a, np.float32), np.asarray(f, np.float32), **_tol(jnp.float32)
    )
    np.testing.assert_allclose(
        np.asarray(a, np.float32), np.asarray(p, np.float32), **_tol(jnp.float32)
    )


def test_spec_pages_bound_exact():
    """A pages_bound covering committed + in-flight pages is exact."""
    args = _windows([(13, 3), (6, 2)], 3)
    full = pallas_spec(*args)
    bounded = pallas_spec(*args, pages_bound=2)
    np.testing.assert_allclose(np.asarray(full), np.asarray(bounded), atol=1e-6)
    via_ops = ops.spec_verify(*args, backend="flash", pages_bound=2)
    oracle = ref.spec_verify(*args)
    np.testing.assert_allclose(
        np.asarray(oracle, np.float32), np.asarray(via_ops, np.float32),
        **_tol(jnp.float32),
    )


def test_spec_pad_rows_are_zero():
    """Window-pad rows and idle slots must come back exactly zero (their
    logits feed the rest of the packed forward)."""
    args = _windows([(13, 2), (0, 0)], 4)
    for out in (ops.spec_verify_jnp(*args), pallas_spec(*args)):
        o = np.asarray(out)
        assert np.all(o[0, 2:] == 0.0)      # window pad
        assert np.all(o[1] == 0.0)          # idle slot


def test_spec_matches_sequential_paged_decode():
    """Verifying a W-token window in one launch must score every position
    exactly like W sequential one-token paged-decode attention calls."""
    rows, W = [(13, 4), (7, 3)], 4
    q, kp, vp, tables, lens, wlens = _windows(rows, W)
    full = np.asarray(ref.spec_verify(q, kp, vp, tables, lens, wlens))
    for i, (L, wl) in enumerate(rows):
        for w in range(wl):
            one = ref.paged_attention(
                q[i : i + 1, w : w + 1], kp, vp, tables[i : i + 1],
                jnp.asarray([L + w + 1], jnp.int32),
            )
            np.testing.assert_allclose(
                full[i, w], np.asarray(one)[0, 0], rtol=2e-6, atol=2e-6
            )


# ---------------------------------------------------------------------------
# Prompt-lookup drafter
# ---------------------------------------------------------------------------
def test_ngram_propose():
    ctx = np.array([1, 2, 3, 9, 1, 2, 3, 5, 7, 1, 2, 3], np.int32)
    # most recent match with a FULL continuation wins: (1,2,3) recurs at
    # 4..6 (5 continuation tokens) and 0..2 (8); for short drafts the later
    # match is preferred, longer drafts walk back to the earlier one
    assert ngram_propose(ctx, 3, 2) == [5, 7]
    assert ngram_propose(ctx, 3, 5) == [5, 7, 1, 2, 3]
    assert ngram_propose(ctx, 3, 8) == [9, 1, 2, 3, 5, 7, 1, 2]
    assert ngram_propose(ctx, 4, 4) == []               # (7,1,2,3) never recurs
    assert ngram_propose(ctx, 3, 0) == []               # no draft budget
    assert ngram_propose(ctx[:3], 3, 4) == []           # context too short
    ctx2 = np.array([2, 3, 8, 2, 3, 6, 2, 3], np.int32)
    assert ngram_propose(ctx2, 2, 1) == [6]
    # a short repetition period must not cap the draft: every (4,5) match
    # near the end has < 4 continuation tokens, the early one has plenty
    ctx3 = np.array([9, 4, 5, 4, 5, 4, 5, 4, 5], np.int32)
    assert ngram_propose(ctx3, 2, 4) == [4, 5, 4, 5]


# ---------------------------------------------------------------------------
# Speculative serving pipeline
# ---------------------------------------------------------------------------
def _engine(max_seq=128, num_slots=3):
    cfg = get_config("glm4-9b", reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, ServingEngine(model, params, max_batch=num_slots, max_seq=max_seq)


def test_serve_paged_spec_bit_identical():
    """Greedy tokens with spec_k > 0 are bit-identical to the non-spec paged
    engine and to serve_continuous — random-init greedy continuations cycle,
    so prompt-lookup genuinely accepts drafts here (asserted)."""
    cfg, engine = _engine()
    rng = np.random.default_rng(7)
    prompts = [
        rng.integers(0, cfg.vocab_size, (n,)).astype(np.int32) for n in (5, 9, 7, 4)
    ]
    reqs = lambda: [
        ServeRequest(request_id=i, prompt=p, max_new_tokens=m)
        for i, (p, m) in enumerate(zip(prompts, (24, 16, 30, 12)))
    ]
    cont = engine.serve_continuous(reqs(), num_slots=2)
    nonspec = engine.serve_paged(reqs(), num_slots=3, page_size=4,
                                 prefill_budget=16)
    spec = engine.serve_paged(reqs(), num_slots=3, page_size=4,
                              prefill_budget=16, spec_k=3)
    by_id = {r.request_id: r for r in cont.results}
    for r in nonspec.results + spec.results:
        np.testing.assert_array_equal(r.tokens, by_id[r.request_id].tokens)
    assert spec.spec_k == 3
    assert spec.spec_stats["draft_accepted"] > 0      # speculation really fired
    assert spec.steps < nonspec.steps                 # accepted drafts save steps
    assert nonspec.spec_stats == {}
    # total emitted tokens are conserved whatever the acceptance pattern
    assert spec.total_tokens == nonspec.total_tokens


def test_serve_paged_spec_rejection_rollback():
    """Lookup-hostile prompts (tiny alphabet: n-grams always match but
    continuations disagree) force mid-draft rejections; with page_size=2
    rejected suffixes straddle page boundaries, so rollback must hand fresh
    pages back — and tokens still match the non-spec path exactly."""
    cfg, engine = _engine(max_seq=64)
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, 4, (12,)).astype(np.int32) for _ in range(3)]
    reqs = lambda: [
        ServeRequest(request_id=i, prompt=p, max_new_tokens=14)
        for i, p in enumerate(prompts)
    ]
    nonspec = engine.serve_paged(reqs(), num_slots=3, page_size=2,
                                 prefill_budget=8)
    spec = engine.serve_paged(reqs(), num_slots=3, page_size=2,
                              prefill_budget=8, spec_k=3, spec_ngram=1)
    by_id = {r.request_id: r for r in nonspec.results}
    for r in spec.results:
        np.testing.assert_array_equal(r.tokens, by_id[r.request_id].tokens)
    s = spec.spec_stats
    assert s["draft_proposed"] > s["draft_accepted"]  # rejections happened
    assert s["rollback_pages"] > 0                    # a draft opened a page
    # the page pool is fully reconciled: every request retired cleanly
    assert spec.peak_pages_in_use <= spec.num_pages


def test_serve_paged_spec_preemption_identical_tokens():
    """Speculation under page pressure (overcommit + preemption + rollback)
    still produces the continuous engine's exact greedy tokens."""
    cfg, engine = _engine(max_seq=32)
    rng = np.random.default_rng(3)
    prompts = [
        rng.integers(0, cfg.vocab_size, (n,)).astype(np.int32) for n in (9, 8, 7, 5)
    ]
    reqs = lambda: [
        ServeRequest(request_id=i, prompt=p, max_new_tokens=m)
        for i, (p, m) in enumerate(zip(prompts, (10, 8, 12, 6)))
    ]
    cont = engine.serve_continuous(reqs(), num_slots=2)
    spec = engine.serve_paged(
        reqs(), num_slots=3, page_size=4, num_pages=7, prefill_chunk=4,
        overcommit=10.0, prefill_budget=8, spec_k=3,
    )
    assert spec.preemptions > 0
    by_id = {r.request_id: r for r in cont.results}
    for r in spec.results:
        np.testing.assert_array_equal(r.tokens, by_id[r.request_id].tokens)


def test_serve_paged_spec_drafts_never_preempt():
    """Speculative demand must never evict live work: when the pool can't
    grow a page for draft tokens, the draft is trimmed to the pages the
    slot already holds (a draft-driven self-preemption of the only request
    would otherwise recompute-loop forever).  Exactly-sized pool: the
    non-spec run never preempts, so the spec run must not either."""
    cfg, engine = _engine(max_seq=64)
    rng = np.random.default_rng(23)
    prompt = rng.integers(0, cfg.vocab_size, (8,)).astype(np.int32)
    # pool sized exactly for prompt + generation: num_pages = pages + scratch
    req = lambda: [ServeRequest(request_id=0, prompt=prompt, max_new_tokens=24)]
    num_pages = (8 + 24) // 4 + 1
    base = engine.serve_paged(req(), num_slots=1, page_size=4,
                              num_pages=num_pages, prefill_budget=8,
                              overcommit=4.0)
    assert base.preemptions == 0
    spec = engine.serve_paged(req(), num_slots=1, page_size=4,
                              num_pages=num_pages, prefill_budget=8,
                              overcommit=4.0, spec_k=4)
    assert spec.preemptions == 0
    np.testing.assert_array_equal(spec.results[0].tokens, base.results[0].tokens)


def test_serve_paged_spec_ledger_accounting():
    """Per-request counters and the run ledger agree; accepted <= proposed;
    drafting never overruns a request's token budget."""
    cfg, engine = _engine()
    rng = np.random.default_rng(11)
    prompts = [rng.integers(0, cfg.vocab_size, (6,)).astype(np.int32)
               for _ in range(3)]
    budgets = (20, 3, 1)
    spec = engine.serve_paged(
        [ServeRequest(request_id=i, prompt=p, max_new_tokens=m)
         for i, (p, m) in enumerate(zip(prompts, budgets))],
        num_slots=3, page_size=4, prefill_budget=16, spec_k=4,
    )
    s = spec.spec_stats
    assert s["draft_accepted"] <= s["draft_proposed"]
    assert 0.0 <= s["acceptance_rate"] <= 1.0
    assert s["draft_proposed"] == sum(r.draft_proposed for r in spec.results)
    assert s["draft_accepted"] == sum(r.draft_accepted for r in spec.results)
    for r, m in zip(spec.results, budgets):
        assert len(r.tokens) == m              # acceptance never overshoots
    # max_new_tokens=1 finishes at prefill: nothing may ever be drafted
    assert spec.results[2].draft_proposed == 0


def test_serve_paged_spec_compile_cap():
    """One verify variant per (ctx-pages bucket, window) — however ragged
    the prompts and whatever the acceptance pattern, k is a config knob, not
    a per-step shape; a warmed second run adds zero variants."""
    cfg, engine = _engine(max_seq=64, num_slots=4)
    rng = np.random.default_rng(13)
    prompts = [
        rng.integers(0, cfg.vocab_size, (n,)).astype(np.int32)
        for n in (3, 11, 17, 6, 9, 14)
    ]
    reqs = lambda: [
        ServeRequest(request_id=i, prompt=p, max_new_tokens=24)
        for i, p in enumerate(prompts)
    ]
    first = engine.serve_paged(reqs(), num_slots=4, page_size=4,
                               prefill_budget=16, spec_k=3)
    # verify launches are always spec_k+1 wide; draft-free boundaries reuse
    # the plain fused decode variants; ctx buckets are pow2 (log)
    max_buckets = 1 + max(64 // 4, 1).bit_length()
    assert 0 < first.compile_stats["spec_decode"] <= max_buckets
    assert first.compile_stats["paged_decode"] <= max_buckets
    second = engine.serve_paged(reqs(), num_slots=4, page_size=4,
                                prefill_budget=16, spec_k=3)
    assert second.compile_stats["spec_decode"] == 0
    assert sum(second.compile_stats.values()) == 0


def test_serve_paged_itl_recorded():
    cfg, engine = _engine()
    rng = np.random.default_rng(17)
    prompts = [rng.integers(0, cfg.vocab_size, (5,)).astype(np.int32)
               for _ in range(2)]
    stats = engine.serve_paged(
        [ServeRequest(request_id=i, prompt=p, max_new_tokens=6)
         for i, p in enumerate(prompts)],
        num_slots=2, page_size=4, prefill_budget=8, spec_k=2,
    )
    for r in stats.results:
        assert r.itl_p99_s >= r.itl_p50_s >= 0.0
        assert r.itl_p99_s > 0.0               # 6 tokens -> real gaps exist
    assert stats.itl_p99_ms >= stats.itl_p50_ms > 0.0
    assert stats.decode_s > 0.0


def test_spec_knob_validation():
    cfg, engine = _engine()
    req = [ServeRequest(request_id=0,
                        prompt=np.zeros((4,), np.int32), max_new_tokens=2)]
    with pytest.raises(ValueError):
        engine.serve_paged(req, spec_k=-1)
    with pytest.raises(ValueError):
        engine.serve_paged(req, spec_k=2, spec_ngram=0)


# ---------------------------------------------------------------------------
# SpecLedger / PageTable.truncate
# ---------------------------------------------------------------------------
def test_spec_ledger():
    l = SpecLedger()
    l.record(0, 3, 2)
    l.record(0, 2, 2)
    l.record(1, 4, 0)
    l.record_launch(True)
    l.record_launch(False)
    l.record_rollback(2)
    assert l.of(0) == (5, 4)
    assert l.of(7) == (0, 0)
    s = l.stats()
    assert s["draft_proposed"] == 9.0
    assert s["draft_accepted"] == 4.0
    assert s["acceptance_rate"] == pytest.approx(4 / 9)
    assert s["spec_launches"] == 1.0
    assert s["fallback_steps"] == 1.0
    assert s["rollback_pages"] == 2.0
    with pytest.raises(ValueError):
        l.record(0, 1, 2)                      # accepted > proposed
    with pytest.raises(ValueError):
        l.record(0, -1, 0)
    with pytest.raises(ValueError):
        l.record_rollback(-1)


def test_page_table_truncate():
    t = PageTable(2, 4, scratch_page=0)
    t.assign(0, [5, 6, 7])
    assert t.truncate(0, 3) == []              # nothing past keep
    assert t.truncate(0, 1) == [6, 7]
    assert t.pages_of(0) == [5]
    assert list(t.table[0]) == [5, 0, 0, 0]
    assert t.truncate(1, 2) == []              # empty slot is a no-op
    with pytest.raises(ValueError):
        t.truncate(0, -1)


# ---------------------------------------------------------------------------
# Analysis: acceptance-rate section + ITL summary
# ---------------------------------------------------------------------------
def _spec_span(begin, end, **tags):
    return Span(
        name="spec:verify", level=TraceLevel.SYSTEM, trace_id="t",
        begin=begin, end=end, tags=tags,
    )


def test_spec_decode_summary_and_section():
    spans = [
        _spec_span(0.0, 0.1, window=4, slots=2, proposed=6, accepted=4, emitted=6),
        _spec_span(0.2, 0.3, window=4, slots=1, proposed=3, accepted=0, emitted=1),
        Span(name="pages:occupancy", level=TraceLevel.SYSTEM, trace_id="t"),
    ]
    s = spec_decode_summary(spans)
    assert s["spec_launches"] == 2.0
    assert s["window"] == 4.0
    assert s["draft_proposed"] == 9.0
    assert s["draft_accepted"] == 4.0
    assert s["acceptance_rate"] == pytest.approx(4 / 9)
    assert s["emitted_tokens"] == 7.0
    assert s["mean_tokens_per_launch"] == pytest.approx(7 / 3)
    assert s["emitted_tokens_per_s"] == pytest.approx(7 / 0.2, rel=1e-6)
    section = spec_decode_section(spans)
    assert "acceptance_rate" in section
    assert spec_decode_section([]) == ""


def test_itl_summary():
    s = itl_summary([0.01, 0.02, 0.03, 0.1])
    assert s["samples"] == 4.0
    assert s["itl_p50_ms"] == pytest.approx(20.0)
    assert s["itl_p99_ms"] == pytest.approx(100.0)
    assert itl_summary([]) == {}


def test_serve_paged_spec_emits_verify_events():
    from repro.core.tracing import Tracer, TracingServer

    cfg, engine = _engine()
    rng = np.random.default_rng(19)
    prompts = [rng.integers(0, cfg.vocab_size, (7,)).astype(np.int32)
               for _ in range(2)]
    server = TracingServer()
    tracer = Tracer("t", server)
    stats = engine.serve_paged(
        [ServeRequest(request_id=i, prompt=p, max_new_tokens=16)
         for i, p in enumerate(prompts)],
        num_slots=2, page_size=4, prefill_budget=8, spec_k=3, tracer=tracer,
    )
    summary = spec_decode_summary(server.timeline("t"))
    s = stats.spec_stats
    if s["spec_launches"]:
        assert summary["spec_launches"] == s["spec_launches"]
        assert summary["draft_proposed"] == s["draft_proposed"]
        assert summary["draft_accepted"] == s["draft_accepted"]
    else:  # pragma: no cover - workload always drafts in practice
        assert summary == {}
