"""Tensor-parallel paged serving over a host-device mesh.

These tests need forced host devices:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m pytest tests/test_tp_serving.py

Without the flag (plain tier-1 runs) every mesh-hungry test skips; the
tp=1 / fallback tests always run.  Coverage:

* the three serving kernels (paged_attention / varlen_prefill /
  spec_verify) under shard_map head splits at tp in {1, 2, 4}, against
  their ``ref.py`` oracles AND bit-exactly against the unsharded dispatch
  (heads never mix inside attention, so head-split blocks are exact) —
  ragged lengths, page-straddling contexts, bf16 pools;
* end-to-end ``serve_paged`` greedy-token bit-identity, tp=2 vs tp=1,
  across packed/chunked x spec_k 0/2 x prefix-cache on/off x preemption;
* ``make_host_mesh`` and the non-divisible-heads replication fallback.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.kernels import ops, ref
from repro.launch.mesh import make_host_mesh
from repro.models import build_model
from repro.serve.engine import ServeRequest, ServingEngine
from repro.sharding.specs import (
    heads_shard_axis,
    serve_rules,
    set_activation_rules,
    tp_degree,
)


def requires_devices(n):
    return pytest.mark.skipif(
        jax.device_count() < n,
        reason=f"needs {n} devices (XLA_FLAGS="
               f"--xla_force_host_platform_device_count={n})",
    )


def _tol(dtype):
    return (
        dict(rtol=2e-2, atol=2e-2)
        if dtype == jnp.bfloat16
        else dict(rtol=1e-5, atol=5e-5)
    )


def _rules_for(tp):
    return serve_rules(make_host_mesh(tp=tp))


# ---------------------------------------------------------------------------
# kernel workloads: ragged lengths, page-straddling contexts
# ---------------------------------------------------------------------------
H, KVH, DH = 8, 4, 16
PAGE = 8


def _pools(rng, num_pages, dtype):
    k = jnp.asarray(rng.standard_normal((num_pages, PAGE, KVH, DH)), dtype)
    v = jnp.asarray(rng.standard_normal((num_pages, PAGE, KVH, DH)), dtype)
    return k, v


def _paged_decode_case(dtype):
    rng = np.random.default_rng(0)
    k_pages, v_pages = _pools(rng, 24, dtype)
    b, max_pages = 4, 4
    q = jnp.asarray(rng.standard_normal((b, 1, H, DH)), dtype)
    table = jnp.asarray(
        rng.permutation(np.arange(1, 24))[: b * max_pages].reshape(b, max_pages),
        jnp.int32,
    )
    # ragged: mid-page, page-straddling, single token, near-full
    lengths = jnp.asarray([5, 13, 1, 27], jnp.int32)
    return q, k_pages, v_pages, table, lengths


def _varlen_case(dtype):
    rng = np.random.default_rng(1)
    k_pages, v_pages = _pools(rng, 24, dtype)
    C, max_pages = 4, 4
    # page-aligned spans (the packed layout contract): 16 + 8 + 24 + 16 = 64
    spans = [16, 8, 24, 16]
    T = sum(spans)
    cu = np.zeros((C + 1,), np.int32)
    cu[1:] = np.cumsum(spans)
    chunk_lens = np.asarray([13, 8, 21, 10], np.int32)      # ragged real tokens
    chunk_pos0 = np.asarray([0, 16, 8, 0], np.int32)        # page-aligned starts
    tables = rng.permutation(np.arange(1, 24))[: C * max_pages].reshape(
        C, max_pages
    ).astype(np.int32)
    q = jnp.asarray(rng.standard_normal((T, H, DH)), dtype)
    k = jnp.asarray(rng.standard_normal((T, KVH, DH)), dtype)
    v = jnp.asarray(rng.standard_normal((T, KVH, DH)), dtype)
    return (
        q, k, v, k_pages, v_pages,
        jnp.asarray(cu), jnp.asarray(chunk_lens), jnp.asarray(chunk_pos0),
        jnp.asarray(tables),
    )


def _spec_case(dtype):
    rng = np.random.default_rng(2)
    k_pages, v_pages = _pools(rng, 24, dtype)
    b, W, max_pages = 4, 3, 4
    q = jnp.asarray(rng.standard_normal((b, W, H, DH)), dtype)
    table = jnp.asarray(
        rng.permutation(np.arange(1, 24))[: b * max_pages].reshape(b, max_pages),
        jnp.int32,
    )
    # window starts are NOT page-aligned; row 2 is idle (window_len 0)
    lengths = jnp.asarray([5, 14, 3, 26], jnp.int32)
    window_lens = jnp.asarray([3, 1, 0, 2], jnp.int32)
    return q, k_pages, v_pages, table, lengths, window_lens


KERNEL_TPS = [1, 2, 4]


@pytest.mark.parametrize("tp", KERNEL_TPS)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_attention_tp_matches_oracle(tp, dtype):
    if jax.device_count() < tp:
        pytest.skip(f"needs {tp} devices")
    q, kp, vp, table, lengths = _paged_decode_case(dtype)
    want = ref.paged_attention(q, kp, vp, table, lengths)
    base = ops.paged_attention(q, kp, vp, table, lengths)
    with set_activation_rules(_rules_for(tp)):
        got = ops.paged_attention(q, kp, vp, table, lengths)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        **_tol(dtype),
    )
    # head-split blocks never mix heads: sharding must be EXACT vs unsharded
    np.testing.assert_array_equal(np.asarray(got), np.asarray(base))


@pytest.mark.parametrize("tp", KERNEL_TPS)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_varlen_prefill_tp_matches_oracle(tp, dtype):
    if jax.device_count() < tp:
        pytest.skip(f"needs {tp} devices")
    args = _varlen_case(dtype)
    want = ref.varlen_prefill(*args)
    base = ops.varlen_prefill(*args)
    with set_activation_rules(_rules_for(tp)):
        got = ops.varlen_prefill(*args)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        **_tol(dtype),
    )
    np.testing.assert_array_equal(np.asarray(got), np.asarray(base))


@pytest.mark.parametrize("tp", KERNEL_TPS)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_spec_verify_tp_matches_oracle(tp, dtype):
    if jax.device_count() < tp:
        pytest.skip(f"needs {tp} devices")
    q, kp, vp, table, lengths, wlens = _spec_case(dtype)
    want = ref.spec_verify(q, kp, vp, table, lengths, wlens)
    base = ops.spec_verify(q, kp, vp, table, lengths, wlens)
    with set_activation_rules(_rules_for(tp)):
        got = ops.spec_verify(q, kp, vp, table, lengths, wlens)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        **_tol(dtype),
    )
    np.testing.assert_array_equal(np.asarray(got), np.asarray(base))


@requires_devices(2)
def test_paged_attention_tp_pages_bound():
    """The static pages_bound slice composes with the shard_map wrap."""
    q, kp, vp, table, lengths = _paged_decode_case(jnp.float32)
    lengths = jnp.minimum(lengths, 2 * PAGE)      # live pages fit the bound
    want = ops.paged_attention(q, kp, vp, table, lengths, pages_bound=2)
    with set_activation_rules(_rules_for(2)):
        got = ops.paged_attention(q, kp, vp, table, lengths, pages_bound=2)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# mesh + rules plumbing
# ---------------------------------------------------------------------------
def test_make_host_mesh_defaults_single_device():
    mesh = make_host_mesh()
    assert mesh.axis_names == ("data", "model")
    assert mesh.shape["model"] == 1 and mesh.shape["data"] == 1


@requires_devices(2)
def test_make_host_mesh_tp_axis():
    mesh = make_host_mesh(tp=2)
    assert mesh.shape["model"] == 2 and mesh.shape["data"] == 1


def test_make_host_mesh_rejects_oversized_tp():
    with pytest.raises(ValueError, match="xla_force_host_platform"):
        make_host_mesh(tp=10 * jax.device_count())
    with pytest.raises(ValueError):
        make_host_mesh(tp=0)


@requires_devices(2)
def test_heads_shard_axis_requires_common_axis():
    rules = _rules_for(2)
    with set_activation_rules(rules):
        assert heads_shard_axis(8, 4) == (rules.mesh, "model")
        # kv heads that don't divide fall back to replication as a UNIT:
        # splitting q-heads but not kv would break GQA grouping
        assert heads_shard_axis(8, 3) is None
        assert heads_shard_axis(3, 3) is None
    assert heads_shard_axis(8, 4) is None         # no rules active


@requires_devices(4)
def test_tp_degree_replication_fallback():
    cfg = get_config("glm4-9b", reduced=True)     # heads=4, kv=2
    assert tp_degree(_rules_for(2), cfg.num_heads, cfg.num_kv_heads) == 2
    assert tp_degree(_rules_for(4), cfg.num_heads, cfg.num_kv_heads) == 1
    assert tp_degree(None, cfg.num_heads, cfg.num_kv_heads) == 1


# ---------------------------------------------------------------------------
# end-to-end: serve_paged tp=2 bit-identical to tp=1
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def _served_model():
    cfg = get_config("glm4-9b", reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _requests(cfg, shared_prefix=False):
    rng = np.random.default_rng(7)
    if shared_prefix:
        prefix = rng.integers(0, cfg.vocab_size, (8,)).astype(np.int32)
        prompts = [
            np.concatenate([prefix, rng.integers(0, cfg.vocab_size, (n,))
                            .astype(np.int32)])
            for n in (5, 3, 7, 2)
        ]
    else:
        prompts = [
            rng.integers(0, cfg.vocab_size, (n,)).astype(np.int32)
            for n in (5, 9, 13, 4)
        ]
    return [
        ServeRequest(request_id=i, prompt=p, max_new_tokens=m)
        for i, (p, m) in enumerate(zip(prompts, (6, 4, 8, 3)))
    ]


@requires_devices(2)
@pytest.mark.parametrize("prefill_mode", ["packed", "chunked"])
@pytest.mark.parametrize("spec_k", [0, 2])
@pytest.mark.parametrize("prefix_cache", [False, True])
def test_serve_paged_tp2_bit_identical(_served_model, prefill_mode, spec_k,
                                       prefix_cache):
    cfg, model, params = _served_model
    kwargs = dict(
        num_slots=3, page_size=8, num_pages=40, prefill_mode=prefill_mode,
        spec_k=spec_k, prefix_cache=prefix_cache,
    )
    base_eng = ServingEngine(model, params, max_batch=3, max_seq=64)
    base = base_eng.serve_paged(_requests(cfg, prefix_cache), **kwargs)
    eng = ServingEngine(
        model, params, max_batch=3, max_seq=64, rules=_rules_for(2)
    )
    assert eng.tp == 2
    got = eng.serve_paged(_requests(cfg, prefix_cache), **kwargs)
    assert got.tp == 2 and base.tp == 1
    by_id = {r.request_id: r for r in base.results}
    for r in got.results:
        np.testing.assert_array_equal(r.tokens, by_id[r.request_id].tokens)
    if prefix_cache:
        assert got.saved_prefill_tokens == base.saved_prefill_tokens


@requires_devices(2)
def test_serve_paged_tp2_preemption_bit_identical(_served_model):
    """Page pressure (overcommitted tiny pool) preempts and recovers under
    tp=2 exactly as at tp=1 — same preemptions, same greedy tokens."""
    cfg, model, params = _served_model
    rng = np.random.default_rng(3)
    prompts = [
        rng.integers(0, cfg.vocab_size, (n,)).astype(np.int32)
        for n in (9, 8, 7, 5)
    ]
    reqs = lambda: [
        ServeRequest(request_id=i, prompt=p, max_new_tokens=m)
        for i, (p, m) in enumerate(zip(prompts, (10, 8, 12, 6)))
    ]
    kwargs = dict(num_slots=3, page_size=4, num_pages=7, prefill_chunk=4,
                  overcommit=10.0)
    base_eng = ServingEngine(model, params, max_batch=3, max_seq=32)
    base = base_eng.serve_paged(reqs(), **kwargs)
    assert base.preemptions > 0
    eng = ServingEngine(
        model, params, max_batch=3, max_seq=32, rules=_rules_for(2)
    )
    got = eng.serve_paged(reqs(), **kwargs)
    assert got.preemptions == base.preemptions
    by_id = {r.request_id: r for r in base.results}
    for r in got.results:
        np.testing.assert_array_equal(r.tokens, by_id[r.request_id].tokens)


@requires_devices(2)
def test_serve_paged_tp2_emits_collective_events(_served_model):
    from repro.core.analysis import tp_summary
    from repro.core.tracing import Tracer, TracingServer

    cfg, model, params = _served_model
    server = TracingServer()
    tracer = Tracer("tp-e2e", server)
    eng = ServingEngine(
        model, params, max_batch=3, max_seq=64, rules=_rules_for(2)
    )
    eng.serve_paged(_requests(cfg), num_slots=3, page_size=8, num_pages=40,
                    tracer=tracer)
    summary = tp_summary(server.timeline("tp-e2e"))
    assert summary["tp"] == 2.0
    assert summary["sharded_launches"] > 0
    # every collective here is a psum (no rs_block_outputs): ring all-reduce
    # moves 2(tp-1)/tp of the payload -> equal at tp=2
    assert summary["psum_count"] > 0
    assert summary["psum_moved_bytes"] == summary["psum_payload_bytes"]
    assert summary["total_moved_bytes"] == summary["psum_moved_bytes"]


@requires_devices(2)
def test_serve_paged_tp2_reduce_scatter_lever(_served_model):
    """rs_block_outputs keeps tokens bit-identical and halves the analytic
    wire traffic on seq-shardable (prefill) launches."""
    from repro.core.analysis import tp_summary
    from repro.core.tracing import Tracer, TracingServer

    cfg, model, params = _served_model
    base_eng = ServingEngine(model, params, max_batch=3, max_seq=64)
    base = base_eng.serve_paged(_requests(cfg), num_slots=3, page_size=8,
                                num_pages=40)
    server = TracingServer()
    tracer = Tracer("tp-rs", server)
    rules = serve_rules(make_host_mesh(tp=2), rs_block_outputs=True)
    eng = ServingEngine(model, params, max_batch=3, max_seq=64, rules=rules)
    got = eng.serve_paged(_requests(cfg), num_slots=3, page_size=8,
                          num_pages=40, tracer=tracer)
    by_id = {r.request_id: r for r in base.results}
    for r in got.results:
        np.testing.assert_array_equal(r.tokens, by_id[r.request_id].tokens)
    summary = tp_summary(server.timeline("tp-rs"))
    assert summary.get("reduce_scatter_count", 0) > 0
    assert (summary["reduce_scatter_moved_bytes"]
            == summary["reduce_scatter_payload_bytes"] / 2)


@requires_devices(4)
def test_serve_paged_tp4_fallback_still_identical(_served_model):
    """glm4-9b reduced has 2 kv heads: tp=4 can't split them, so the rules
    fall back to replication (effective tp 1) — and tokens still match."""
    cfg, model, params = _served_model
    base_eng = ServingEngine(model, params, max_batch=3, max_seq=64)
    base = base_eng.serve_paged(_requests(cfg), num_slots=3, page_size=8,
                                num_pages=40)
    eng = ServingEngine(
        model, params, max_batch=3, max_seq=64, rules=_rules_for(4)
    )
    assert eng.tp == 1
    got = eng.serve_paged(_requests(cfg), num_slots=3, page_size=8,
                          num_pages=40)
    by_id = {r.request_id: r for r in base.results}
    for r in got.results:
        np.testing.assert_array_equal(r.tokens, by_id[r.request_id].tokens)


@requires_devices(2)
def test_serve_paged_tp2_int8_bit_identical(_served_model):
    """Quantized pools shard their scale pools with the kv heads: the int8
    engine at tp=2 must produce the same greedy tokens as int8 at tp=1
    (quantization happens per kv head, so the heads split changes nothing)."""
    cfg, model, params = _served_model
    kwargs = dict(num_slots=3, page_size=8, num_pages=40)
    base_eng = ServingEngine(
        model, params, max_batch=3, max_seq=64, kv_dtype="int8"
    )
    base = base_eng.serve_paged(_requests(cfg), **kwargs)
    eng = ServingEngine(
        model, params, max_batch=3, max_seq=64, rules=_rules_for(2),
        kv_dtype="int8",
    )
    assert eng.tp == 2
    got = eng.serve_paged(_requests(cfg), **kwargs)
    assert got.kv_dtype == "int8" and base.kv_dtype == "int8"
    by_id = {r.request_id: r for r in base.results}
    for r in got.results:
        np.testing.assert_array_equal(r.tokens, by_id[r.request_id].tokens)
