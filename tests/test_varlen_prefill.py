"""Packed varlen prefill: kernel sweeps vs the host-loop oracle, the packed
serving pipeline vs the chunked path (bit-identical greedy tokens), the
prefill token-budget ledger, and per-run compile accounting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.analysis import (
    prefill_saturation_section,
    prefill_saturation_summary,
)
from repro.core.tracing import Span, TraceLevel
from repro.kernels import ops, ref
from repro.kernels.varlen_prefill import varlen_prefill as pallas_varlen
from repro.models import build_model
from repro.serve.engine import ServeRequest, ServingEngine
from repro.serve.scheduler import PrefillBudget

_RNG = np.random.default_rng(42)

PAGE = 8


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=5e-5, atol=5e-5)


def _pack(chunks, T, kvh=2, h=4, d=16, max_pages=6, num_pages=24,
          dtype=jnp.float32):
    """Build a packed workload: ``chunks`` is a list of (real_len,
    ctx_pages); spans are page-aligned, T may leave a buffer tail pad."""
    C = len(chunks)
    cu, lens, pos0 = [0], [], []
    tables = np.zeros((C, max_pages), np.int32)
    nxt = 1
    for c, (n, cp) in enumerate(chunks):
        cu.append(cu[-1] + (n + PAGE - 1) // PAGE * PAGE)
        lens.append(n)
        pos0.append(cp * PAGE)
        for j in range(cp):
            tables[c, j] = nxt
            nxt += 1
    assert cu[-1] <= T and nxt <= num_pages
    mk = lambda shape: jnp.asarray(_RNG.normal(size=shape), dtype)
    return (
        mk((T, h, d)), mk((T, kvh, d)), mk((T, kvh, d)),
        mk((num_pages, PAGE, kvh, d)), mk((num_pages, PAGE, kvh, d)),
        jnp.asarray(np.array(cu, np.int32)),
        jnp.asarray(np.array(lens, np.int32)),
        jnp.asarray(np.array(pos0, np.int32)),
        jnp.asarray(tables),
    )


CASES = [
    # (chunks [(real_len, ctx_pages)], T): ragged lengths, non-divisible
    # chunk tails, empty chunk rows, context pages, buffer tail pad
    ([(5, 0), (8, 2), (3, 1)], 32),
    ([(13, 1), (0, 0), (7, 0)], 24),
    ([(8, 3), (16, 0), (2, 2), (5, 1)], 40),
    ([(21, 2)], 24),
]


@pytest.mark.parametrize("chunks,T", CASES)
@pytest.mark.parametrize("window", [None, 5])
def test_varlen_jnp_vs_oracle(chunks, T, window):
    args = _pack(chunks, T)
    a = ref.varlen_prefill(*args, window=window)
    f = ops.varlen_prefill_jnp(*args, window=window)
    np.testing.assert_allclose(
        np.asarray(a, np.float32), np.asarray(f, np.float32), **_tol(jnp.float32)
    )


@pytest.mark.parametrize("chunks,T", CASES)
@pytest.mark.parametrize("window", [None, 5])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pallas_varlen_vs_oracle(chunks, T, window, dtype):
    args = _pack(chunks, T, dtype=dtype)
    a = ref.varlen_prefill(*args, window=window)
    p = pallas_varlen(*args, window=window)
    assert p.dtype == args[0].dtype
    np.testing.assert_allclose(
        np.asarray(a, np.float32), np.asarray(p, np.float32), **_tol(dtype)
    )


def test_varlen_softcap_and_dispatch():
    args = _pack([(6, 1), (9, 0)], 24)
    a = ref.varlen_prefill(*args, softcap=11.0)
    f = ops.varlen_prefill(*args, softcap=11.0, backend="flash")
    p = ops.varlen_prefill(*args, softcap=11.0, backend="pallas")
    np.testing.assert_allclose(
        np.asarray(a, np.float32), np.asarray(f, np.float32), **_tol(jnp.float32)
    )
    np.testing.assert_allclose(
        np.asarray(a, np.float32), np.asarray(p, np.float32), **_tol(jnp.float32)
    )


def test_varlen_pages_bound_exact():
    """A pages_bound covering every chunk's committed context is exact."""
    args = _pack([(8, 2), (8, 1)], 16)
    full = pallas_varlen(*args)
    bounded = pallas_varlen(*args, pages_bound=2)
    np.testing.assert_allclose(np.asarray(full), np.asarray(bounded), atol=1e-6)
    via_ops = ops.varlen_prefill(*args, backend="flash", pages_bound=2)
    oracle = ref.varlen_prefill(*args)
    np.testing.assert_allclose(
        np.asarray(oracle, np.float32), np.asarray(via_ops, np.float32),
        **_tol(jnp.float32),
    )


def test_varlen_jnp_non_aligned_chunk_boundaries():
    """A page-multiple buffer with NON-page-aligned chunk boundaries must
    take the exact per-token path (a block straddling two chunks would
    otherwise gather the wrong request's context pages)."""
    ps, kvh, h, d, num_pages = 8, 2, 4, 16, 12
    T = 16
    mk = lambda shape: jnp.asarray(_RNG.normal(size=shape), jnp.float32)
    args = (
        mk((T, h, d)), mk((T, kvh, d)), mk((T, kvh, d)),
        mk((num_pages, ps, kvh, d)), mk((num_pages, ps, kvh, d)),
        jnp.asarray([0, 10, 16], jnp.int32),      # boundary at 10: misaligned
        jnp.asarray([10, 6], jnp.int32),
        jnp.asarray([8, 0], jnp.int32),
        jnp.asarray([[1, 0, 0], [0, 0, 0]], jnp.int32),
    )
    a = ref.varlen_prefill(*args)
    f = ops.varlen_prefill_jnp(*args)
    np.testing.assert_allclose(
        np.asarray(a, np.float32), np.asarray(f, np.float32), **_tol(jnp.float32)
    )


def test_varlen_pad_rows_are_zero():
    """Chunk-pad and buffer-tail rows must come back exactly zero (they feed
    the rest of the packed forward)."""
    chunks, T = [(5, 0), (11, 1)], 32
    args = _pack(chunks, T)
    for out in (ops.varlen_prefill_jnp(*args), pallas_varlen(*args)):
        o = np.asarray(out)
        assert np.all(o[5:8] == 0.0)        # chunk 0 pad
        assert np.all(o[8 + 11 : 24] == 0.0)  # chunk 1 pad
        assert np.all(o[24:] == 0.0)        # buffer tail


def test_varlen_no_cross_chunk_leakage():
    """Perturbing one chunk's tokens must not change another chunk's output
    (the packed buffer is attention-isolated per request)."""
    chunks, T = [(8, 0), (8, 0)], 16
    q, k, v, kp, vp, cu, lens, pos0, tables = _pack(chunks, T)
    base = np.asarray(ops.varlen_prefill_jnp(q, k, v, kp, vp, cu, lens, pos0, tables))
    k2 = k.at[8:].add(3.7)
    v2 = v.at[8:].add(-1.9)
    pert = np.asarray(ops.varlen_prefill_jnp(q, k2, v2, kp, vp, cu, lens, pos0, tables))
    np.testing.assert_array_equal(base[:8], pert[:8])
    assert np.abs(base[8:] - pert[8:]).max() > 1e-3


# ---------------------------------------------------------------------------
# Packed serving pipeline
# ---------------------------------------------------------------------------
def _engine(max_seq=32, num_slots=3):
    cfg = get_config("glm4-9b", reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, ServingEngine(model, params, max_batch=num_slots, max_seq=max_seq)


def test_serve_paged_packed_matches_chunked():
    """Greedy tokens from the packed varlen-prefill pipeline are
    bit-identical to the PR 2 chunked path (and both to serve_continuous)."""
    cfg, engine = _engine()
    rng = np.random.default_rng(7)
    prompts = [
        rng.integers(0, cfg.vocab_size, (n,)).astype(np.int32) for n in (5, 9, 7, 4)
    ]
    reqs = lambda: [
        ServeRequest(request_id=i, prompt=p, max_new_tokens=m)
        for i, (p, m) in enumerate(zip(prompts, (6, 4, 8, 3)))
    ]
    cont = engine.serve_continuous(reqs(), num_slots=2)
    chunked = engine.serve_paged(
        reqs(), num_slots=3, page_size=4, prefill_chunk=8, prefill_mode="chunked"
    )
    packed = engine.serve_paged(
        reqs(), num_slots=3, page_size=4, prefill_chunk=8,
        prefill_mode="packed", prefill_budget=16,
    )
    by_id = {r.request_id: r for r in cont.results}
    for r in chunked.results + packed.results:
        np.testing.assert_array_equal(r.tokens, by_id[r.request_id].tokens)
    assert packed.prefill_mode == "packed"
    assert packed.prefill_budget == 16
    assert packed.prefill_tokens == sum(len(p) for p in prompts)
    # coalescing: fewer launches than chunks, budget ledger consistent
    assert packed.prefill_launches < packed.prefill_chunks + len(prompts)
    assert packed.prefill_launches <= chunked.prefill_launches
    assert packed.prefill_budget_stats["granted_tokens"] == packed.prefill_tokens


def test_serve_paged_packed_budget_caps_boundary_tokens():
    """A tight prefill budget spreads one long prompt over several packed
    launches, each granting at most ``prefill_budget`` real tokens."""
    cfg, engine = _engine()
    rng = np.random.default_rng(11)
    prompt = rng.integers(0, cfg.vocab_size, (20,)).astype(np.int32)
    stats = engine.serve_paged(
        [ServeRequest(request_id=0, prompt=prompt, max_new_tokens=2)],
        num_slots=2, page_size=4, prefill_mode="packed", prefill_budget=8,
    )
    assert stats.prefill_budget == 8
    assert stats.prefill_launches >= 3          # 20 tokens / 8-token budget
    assert stats.prefill_budget_stats["granted_tokens"] == 20.0
    # no launch can exceed the budget: utilization is total/steps*budget
    assert stats.prefill_budget_stats["budget_utilization"] <= 1.0
    # tokens left waiting at full boundaries are recorded as starvation
    assert stats.prefill_budget_stats["starved_tokens"] > 0


def test_serve_paged_packed_preemption_identical_tokens():
    """Packed prefill under page pressure (overcommit + preemption) still
    produces the chunked path's exact greedy tokens."""
    cfg, engine = _engine()
    rng = np.random.default_rng(3)
    prompts = [
        rng.integers(0, cfg.vocab_size, (n,)).astype(np.int32) for n in (9, 8, 7, 5)
    ]
    reqs = lambda: [
        ServeRequest(request_id=i, prompt=p, max_new_tokens=m)
        for i, (p, m) in enumerate(zip(prompts, (10, 8, 12, 6)))
    ]
    cont = engine.serve_continuous(reqs(), num_slots=2)
    packed = engine.serve_paged(
        reqs(), num_slots=3, page_size=4, num_pages=7, prefill_chunk=4,
        overcommit=10.0, prefill_mode="packed", prefill_budget=8,
    )
    assert packed.preemptions > 0
    by_id = {r.request_id: r for r in cont.results}
    for r in packed.results:
        np.testing.assert_array_equal(r.tokens, by_id[r.request_id].tokens)


def test_serve_paged_packed_single_compile():
    """However ragged the prompt mix, the packed pipeline compiles ONE
    prefill variant per (buffer, chunk-rows, table, ctx-bucket) shape —
    not one per chunk length x offset like the chunked path."""
    cfg, engine = _engine(max_seq=64, num_slots=4)
    rng = np.random.default_rng(5)
    prompts = [
        rng.integers(0, cfg.vocab_size, (n,)).astype(np.int32)
        for n in (3, 11, 17, 6, 9, 14)
    ]
    reqs = lambda: [
        ServeRequest(request_id=i, prompt=p, max_new_tokens=2)
        for i, p in enumerate(prompts)
    ]
    packed = engine.serve_paged(
        reqs(), num_slots=4, page_size=4, prefill_mode="packed",
        prefill_budget=16,
    )
    # ctx-pages pow2 buckets are the only extra variants (log, not per-shape)
    assert packed.compile_stats["packed_prefill"] <= 3
    assert packed.compile_stats["paged_prefill"] == 0
    chunked = engine.serve_paged(
        reqs(), num_slots=4, page_size=4, prefill_chunk=8,
        prefill_mode="chunked",
    )
    assert chunked.compile_stats["paged_prefill"] > packed.compile_stats["packed_prefill"]


def test_compile_stats_per_instance_and_per_run():
    """Engines built in one process never see each other's compile counts,
    and a run's PagedStats reports only its own delta (a warmed second run
    reports zero new compiles)."""
    cfg, e1 = _engine()
    rng = np.random.default_rng(9)
    prompts = [rng.integers(0, cfg.vocab_size, (6,)).astype(np.int32) for _ in range(2)]
    reqs = lambda: [
        ServeRequest(request_id=i, prompt=p, max_new_tokens=2)
        for i, p in enumerate(prompts)
    ]
    first = e1.serve_paged(reqs(), num_slots=2, page_size=4, prefill_budget=8)
    assert sum(first.compile_stats.values()) > 0
    second = e1.serve_paged(reqs(), num_slots=2, page_size=4, prefill_budget=8)
    assert sum(second.compile_stats.values()) == 0   # cache warm: no new jits
    assert sum(e1.compile_stats().values()) == sum(first.compile_stats.values())
    _, e2 = _engine()
    assert all(v == 0 for v in e2.compile_stats().values())


# ---------------------------------------------------------------------------
# PrefillBudget ledger
# ---------------------------------------------------------------------------
def test_prefill_budget_ledger():
    b = PrefillBudget(16)
    with pytest.raises(ValueError):
        PrefillBudget(0)
    b.begin_step()
    assert b.grant(10) == 10
    assert b.grant(10) == 6                  # capped by the remaining budget
    assert b.grant(5) == 0
    with pytest.raises(ValueError):
        b.grant(-1)
    b.begin_step()
    assert b.remaining == 16                 # fresh window per boundary
    assert b.grant(4) == 4
    b.defer(7)                               # demand left waiting this step
    with pytest.raises(ValueError):
        b.defer(-1)
    s = b.stats()
    assert s["steps"] == 2.0
    assert s["granted_tokens"] == 20.0
    assert s["requested_tokens"] == 36.0
    assert s["starved_tokens"] == 16.0
    assert s["budget_utilization"] == pytest.approx(20 / 32)
    assert b.granted_series == [(0, 16), (1, 4)]


# ---------------------------------------------------------------------------
# Prefill-saturation analysis
# ---------------------------------------------------------------------------
def _prefill_span(begin, end, **tags):
    return Span(
        name="prefill:packed", level=TraceLevel.SYSTEM, trace_id="t",
        begin=begin, end=end, tags=tags,
    )


def test_prefill_saturation_summary_and_section():
    spans = [
        _prefill_span(0.0, 0.1, tokens=48, padding=16, chunks=3, buffer=64, budget=64),
        _prefill_span(0.2, 0.3, tokens=32, padding=32, chunks=1, buffer=64, budget=64),
        Span(name="pages:occupancy", level=TraceLevel.SYSTEM, trace_id="t"),
    ]
    s = prefill_saturation_summary(spans)
    assert s["launches"] == 2.0
    assert s["buffer_tokens"] == 64.0
    assert s["prefill_tokens"] == 80.0
    assert s["mean_chunks_per_launch"] == 2.0
    assert s["mean_buffer_utilization"] == pytest.approx(80 / 128)
    assert s["peak_buffer_utilization"] == pytest.approx(48 / 64)
    assert s["pad_fraction"] == pytest.approx(48 / 128)
    assert s["prefill_tokens_per_s"] == pytest.approx(80 / 0.2, rel=1e-6)
    section = prefill_saturation_section(spans)
    assert "mean_buffer_utilization" in section
    assert prefill_saturation_section([]) == ""


def test_serve_paged_packed_emits_prefill_events():
    from repro.core.tracing import Tracer, TracingServer

    cfg, engine = _engine()
    rng = np.random.default_rng(13)
    prompts = [rng.integers(0, cfg.vocab_size, (7,)).astype(np.int32) for _ in range(2)]
    server = TracingServer()
    tracer = Tracer("t", server)
    stats = engine.serve_paged(
        [ServeRequest(request_id=i, prompt=p, max_new_tokens=2)
         for i, p in enumerate(prompts)],
        num_slots=2, page_size=4, prefill_budget=8, tracer=tracer,
    )
    summary = prefill_saturation_summary(server.timeline("t"))
    assert summary["launches"] == float(stats.prefill_launches)
    assert summary["prefill_tokens"] == float(stats.prefill_tokens)
